//! Bit-plane and low-precision similarity kernels (the quantized serving
//! hot path; EXPERIMENTS.md §Perf).
//!
//! Two operand containers + two GEMM-shaped kernels:
//!
//! - [`BitMatrix`] — one sign bit per value, rows padded to whole u64
//!   words. [`xnor_popcount_nt`] computes the ±1 dot product of every
//!   query row against every model row via the XNOR/popcount identity
//!   `<a, b> = D − 2·popcount(a ⊕ b)` (XOR of the zero padding is zero,
//!   so padding never contributes), streaming whole words through
//!   `count_ones` with a 4-way unrolled accumulator.
//! - [`I16Matrix`] — int8-valued fields held in i16 (the +2^(b−1) code is
//!   reachable through stored-state bit flips and must not saturate;
//!   widening i16 multiplies are also the form SIMD likes).
//!   [`i16_matmul_nt`] accumulates in i32 and folds the two per-tensor
//!   scales into the f32 output, register-blocked over 4 model rows like
//!   `matmul_nt`.
//!
//! Both kernels parallelize over query rows via `util::threadpool` and
//! dispatch their inner loops through [`super::simd`] (AVX2 `vpmaddwd` /
//! `vpshufb` popcount, NEON `vmlal`/`vcnt`, scalar fallback).

use super::{simd, Matrix};
use crate::util::threadpool;

/// Sign-bit matrix: bit = 1 encodes "value >= 0" (the same convention as
/// `quant::quantize` at 1 bit). Rows are padded to u64 boundaries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl BitMatrix {
    /// All-zero-bit matrix (every field "negative").
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let words_per_row = cols.div_ceil(64);
        Self { rows, cols, words_per_row, words: vec![0; rows * words_per_row] }
    }

    /// Binarize a dense matrix by sign.
    pub fn from_signs(m: &Matrix) -> Self {
        let mut out = Self::zeros(0, 0);
        Self::from_signs_into(m, &mut out);
        out
    }

    /// [`Self::from_signs`] into a reused container (the B1 query side
    /// re-binarizes every batch; engines keep one scratch so the steady
    /// state allocates nothing). Each padded u64 word is rebuilt whole
    /// from a 64-element slice of the row, so no clear of the recycled
    /// word buffer is needed.
    pub fn from_signs_into(m: &Matrix, out: &mut BitMatrix) {
        let (rows, cols) = (m.rows(), m.cols());
        let words_per_row = cols.div_ceil(64);
        out.rows = rows;
        out.cols = cols;
        out.words_per_row = words_per_row;
        out.words.resize(rows * words_per_row, 0);
        for r in 0..rows {
            let row = m.row(r);
            let base = r * words_per_row;
            for (w, chunk) in row.chunks(64).enumerate() {
                let mut word = 0u64;
                for (i, v) in chunk.iter().enumerate() {
                    if *v >= 0.0 {
                        word |= 1u64 << i;
                    }
                }
                out.words[base + w] = word;
            }
        }
    }

    /// Build from a bit-valued closure (used to lift packed storage into
    /// the row-aligned kernel layout).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> bool) -> Self {
        let mut out = Self::zeros(rows, cols);
        for r in 0..rows {
            let base = r * out.words_per_row;
            for c in 0..cols {
                if f(r, c) {
                    out.words[base + c / 64] |= 1u64 << (c % 64);
                }
            }
        }
        out
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The padded u64 words of one row.
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u64] {
        debug_assert!(r < self.rows);
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Read one bit (tests / debugging).
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        (self.words[r * self.words_per_row + c / 64] >> (c % 64)) & 1 == 1
    }
}

/// Hamming distance between two equal-length word slices (dispatched:
/// AVX2 nibble-LUT popcount / NEON byte popcount / unrolled scalar).
#[inline]
pub fn hamming_words(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    simd::hamming(a, b)
}

/// C[i][j] = <±1 row a_i, ±1 row b_j> = D − 2·hamming(a_i, b_j), as f32.
/// The similarity shape (`A · Bᵀ`), computed entirely on packed words.
pub fn xnor_popcount_nt(a: &BitMatrix, b: &BitMatrix) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    xnor_popcount_nt_into(a, b, &mut out);
    out
}

/// [`xnor_popcount_nt`] into a reused output matrix (every element is
/// written unconditionally, so the recycled buffer needs no clear).
pub fn xnor_popcount_nt_into(a: &BitMatrix, b: &BitMatrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "xnor_popcount_nt width mismatch");
    let (m, n, d) = (a.rows(), b.rows(), a.cols() as i64);
    out.resize(m, n);
    let threads = threadpool::available_threads();
    threadpool::parallel_rows(out.data_mut(), n.max(1), threads, |i, crow| {
        let qwords = a.row_words(i);
        for (j, cv) in crow.iter_mut().enumerate() {
            let ham = hamming_words(qwords, b.row_words(j)) as i64;
            *cv = (d - 2 * ham) as f32;
        }
    });
}

/// Int8-valued matrix in i16 storage with one per-tensor scale:
/// `value = data[i] * scale`.
#[derive(Debug, Clone, PartialEq)]
pub struct I16Matrix {
    rows: usize,
    cols: usize,
    pub scale: f32,
    data: Vec<i16>,
}

impl I16Matrix {
    pub fn new(rows: usize, cols: usize, scale: f32, data: Vec<i16>) -> Self {
        assert_eq!(data.len(), rows * cols, "i16 shape mismatch");
        Self { rows, cols, scale, data }
    }

    /// An empty (0×0) container, for use as a [`Self::quantize_into`]
    /// target that amortizes across batches.
    pub fn empty() -> Self {
        Self { rows: 0, cols: 0, scale: 1.0, data: Vec::new() }
    }

    /// Symmetric per-tensor int8 quantization of a dense matrix — the
    /// same levels as `quant::quantize` at 8 bits (scale = max|x|/127,
    /// round-to-nearest, clamp to ±127).
    pub fn quantize(m: &Matrix) -> Self {
        let mut out = Self::empty();
        Self::quantize_into(m, &mut out);
        out
    }

    /// [`Self::quantize`] into a reused container (the B8 query side
    /// re-quantizes every batch; engines keep one scratch so the steady
    /// state allocates nothing). Both stages run through the dispatched
    /// vector kernels: one max-abs reduction pass (the scale depends on
    /// the global maximum, so it must precede the map), then one
    /// divide/round/clamp/narrow map pass straight into the buffer —
    /// replacing the old two scalar iterator sweeps plus a fresh `Vec`
    /// per call.
    pub fn quantize_into(m: &Matrix, out: &mut I16Matrix) {
        let max_abs = simd::max_abs(m.data());
        let scale = (max_abs / 127.0).max(1e-12);
        out.rows = m.rows();
        out.cols = m.cols();
        out.scale = scale;
        // resize alone: a same-size reuse is a no-op (no redundant
        // zero-fill — the map below writes every element).
        out.data.resize(m.data().len(), 0);
        simd::quantize_i16(m.data(), scale, &mut out.data);
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[i16] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Per-row L2 norms in real units (scale folded in), exact integer
    /// sum-of-squares before the square root.
    pub fn row_norms(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.row_norms_into(&mut out);
        out
    }

    /// [`Self::row_norms`] into a reused buffer (cleared and refilled).
    pub fn row_norms_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend((0..self.rows).map(|r| {
            let ss: i64 = self.row(r).iter().map(|v| *v as i64 * *v as i64).sum();
            self.scale * (ss as f64).sqrt() as f32
        }));
    }
}

/// C = A · Bᵀ over int8-valued operands: i32 accumulation, the two
/// per-tensor scales folded into the f32 result. Register-blocked over 4
/// B rows (each query element loads once for 4 accumulator chains)
/// through the dispatched [`simd::dot_i16_4`].
pub fn i16_matmul_nt(a: &I16Matrix, b: &I16Matrix) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    i16_matmul_nt_into(a, b, &mut out);
    out
}

/// [`i16_matmul_nt`] into a reused output matrix (every element is
/// written unconditionally, so the recycled buffer needs no clear).
pub fn i16_matmul_nt_into(a: &I16Matrix, b: &I16Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "i16_matmul_nt width mismatch");
    let (m, n) = (a.rows(), b.rows());
    let fold = a.scale * b.scale;
    out.resize(m, n);
    let threads = threadpool::available_threads();
    threadpool::parallel_rows(out.data_mut(), n.max(1), threads, |i, crow| {
        let arow = a.row(i);
        let mut j = 0;
        while j + 4 <= n {
            let block = simd::dot_i16_4(arow, b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
            for (cv, acc) in crow[j..j + 4].iter_mut().zip(block) {
                *cv = acc as f32 * fold;
            }
            j += 4;
        }
        for (jj, cv) in crow.iter_mut().enumerate().skip(j) {
            *cv = simd::dot_i16(arow, b.row(jj)) as f32 * fold;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn bitmatrix_from_signs_roundtrip() {
        let m = Matrix::from_vec(2, 5, vec![1.0, -2.0, 0.0, -0.5, 3.0, -1.0, 1.0, 1.0, -1.0, -1.0]);
        let b = BitMatrix::from_signs(&m);
        for r in 0..2 {
            for c in 0..5 {
                assert_eq!(b.get(r, c), m.at(r, c) >= 0.0, "({r},{c})");
            }
        }
        assert_eq!(b.row_words(0).len(), 1);
    }

    #[test]
    fn xnor_matches_sign_dot_across_widths() {
        let mut rng = SplitMix64::new(31);
        for cols in [1usize, 63, 64, 65, 200, 256] {
            let a = Matrix::from_vec(3, cols, rng.normals_f32(3 * cols));
            let b = Matrix::from_vec(5, cols, rng.normals_f32(5 * cols));
            let got = xnor_popcount_nt(&BitMatrix::from_signs(&a), &BitMatrix::from_signs(&b));
            for i in 0..3 {
                for j in 0..5 {
                    let want: f32 = (0..cols)
                        .map(|c| {
                            let sa = if a.at(i, c) >= 0.0 { 1.0f32 } else { -1.0 };
                            let sb = if b.at(j, c) >= 0.0 { 1.0f32 } else { -1.0 };
                            sa * sb
                        })
                        .sum();
                    assert_eq!(got.at(i, j), want, "cols={cols} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn hamming_words_counts_xor_bits() {
        assert_eq!(hamming_words(&[0b1011, 0, u64::MAX], &[0b0001, 0, 0]), 2 + 64);
        assert_eq!(hamming_words(&[], &[]), 0);
    }

    #[test]
    fn i16_quantize_matches_reference_levels() {
        let m = Matrix::from_vec(1, 4, vec![1.0, -0.5, 0.25, -1.0]);
        let q = I16Matrix::quantize(&m);
        assert!((q.scale - 1.0 / 127.0).abs() < 1e-9);
        assert_eq!(q.row(0), &[127, -64, 32, -127]);
    }

    #[test]
    fn i16_matmul_matches_f32_reference() {
        let mut rng = SplitMix64::new(77);
        for (m, k, n) in [(1usize, 7usize, 1usize), (3, 33, 5), (4, 128, 3), (2, 64, 4)] {
            let a = Matrix::from_vec(m, k, rng.normals_f32(m * k));
            let b = Matrix::from_vec(n, k, rng.normals_f32(n * k));
            let qa = I16Matrix::quantize(&a);
            let qb = I16Matrix::quantize(&b);
            let got = i16_matmul_nt(&qa, &qb);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k)
                        .map(|kk| {
                            (qa.row(i)[kk] as f32 * qa.scale) * (qb.row(j)[kk] as f32 * qb.scale)
                        })
                        .sum();
                    let tol = 1e-4 * (1.0 + want.abs());
                    assert!(
                        (got.at(i, j) - want).abs() <= tol,
                        "({i},{j}): {} vs {want}",
                        got.at(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_into_reuses_buffer_and_matches_fresh() {
        let mut rng = SplitMix64::new(91);
        let mut scratch = I16Matrix::empty();
        for cols in [5usize, 64, 100, 17] {
            let m = Matrix::from_vec(2, cols, rng.normals_f32(2 * cols));
            I16Matrix::quantize_into(&m, &mut scratch);
            assert_eq!(scratch, I16Matrix::quantize(&m), "cols={cols}");
        }
    }

    #[test]
    fn i16_row_norms_exact() {
        let q = I16Matrix::new(1, 3, 0.5, vec![3, 4, 0]);
        let norms = q.row_norms();
        assert!((norms[0] - 2.5).abs() < 1e-6);
    }
}

//! Explicit-SIMD kernels with one-time runtime dispatch.
//!
//! Every f32/int/popcount kernel the tensor layer runs hot lives here
//! three times: a scalar reference ([`scalar`]), an AVX2+FMA version
//! (`x86_64`), and a NEON version (`aarch64`), all behind dispatching
//! wrappers (`dot`, [`axpy`], [`hamming`], [`encode_row`], …) so call
//! sites above the tensor layer never change.
//!
//! # Dispatch contract
//!
//! - The path is detected **once per process** ([`path`], cached in a
//!   `OnceLock`): AVX2+FMA or NEON when the CPU reports them, scalar
//!   otherwise. Setting `LOGHD_FORCE_SCALAR=1` (any value other than
//!   `0`/empty) forces the scalar path — the escape hatch for A/B
//!   benching and for debugging a suspected kernel divergence.
//! - [`scalar`] is the *reference*: the SIMD paths must agree with it
//!   bit-for-bit on the integer kernels ([`dot_i16`], [`hamming`],
//!   [`quantize_i16`]) and within 1e-5 relative on the f32 reductions
//!   (FMA and lane-order differences only). `rust/tests/properties.rs`
//!   pins both across widths and unaligned tails.
//! - [`cos_poly`] (and the vector epilogues built from it) stays within
//!   1e-6 absolute of libm `cos` for |x| ≤ [`POLY_COS_MAX`] — the
//!   encoder's post-GEMM angles are a few tens at most. Beyond that
//!   domain (adversarial client features), every path falls back to
//!   libm for the affected values, so outputs stay bounded and
//!   libm-accurate everywhere. The scalar *encode* path keeps libm
//!   `cos` throughout so it remains the Python-parity reference.
//! - The i16 kernels require int8-valued operands (|v| ≤ 128, the
//!   [`super::I16Matrix`] container contract); i32 accumulation is then
//!   exact for any row width the models use (overflow needs ≥ 2^16
//!   elements per row).
//!
//! The fused encoder path additionally needs the projection matrix in
//! column-panel layout ([`PackedPanels`]): panels of [`PANEL`] columns
//! stored k-major, so the GEMM inner loop is one broadcast-FMA per
//! feature per panel with the output tile resident in registers, and the
//! cos/bias/centering epilogue runs on the tile before it is stored.

use std::sync::OnceLock;

use super::Matrix;

pub mod scalar;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

/// Column-panel width of [`PackedPanels`] (one AVX2 register; two NEON
/// registers).
pub const PANEL: usize = 8;

/// Which kernel family [`path`] selected for this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    /// Portable reference kernels (also the forced-scalar escape hatch).
    Scalar,
    /// AVX2 + FMA (x86_64, runtime-detected).
    Avx2Fma,
    /// NEON (aarch64).
    Neon,
}

impl Path {
    /// Short label for logs / bench JSON.
    pub fn label(self) -> &'static str {
        match self {
            Path::Scalar => "scalar",
            Path::Avx2Fma => "avx2+fma",
            Path::Neon => "neon",
        }
    }
}

/// The dispatch decision for this process (detected once, then cached).
pub fn path() -> Path {
    static PATH: OnceLock<Path> = OnceLock::new();
    *PATH.get_or_init(|| {
        let forced = std::env::var("LOGHD_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if forced {
            return Path::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Path::Avx2Fma;
        }
        #[cfg(target_arch = "aarch64")]
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Path::Neon;
        }
        Path::Scalar
    })
}

/// Label of the active dispatch path (for bench reports).
pub fn path_label() -> &'static str {
    path().label()
}

// --- Cody–Waite range reduction + cephes-style minimax polynomials.
//
// π/2 split into three f32 terms with short mantissas so `q * term` is
// exact for the quotients the encoder produces; the residual r lands in
// [-π/4, π/4] (± a few ulp) where the polynomials are accurate to ~9e-8
// absolute (validated numerically; pinned at 1e-6 by the property test).
#[allow(clippy::excessive_precision)]
mod consts {
    pub const PIO2_HI: f32 = 1.5703125;
    pub const PIO2_MID: f32 = 4.8375129699707031e-4;
    pub const PIO2_LO: f32 = 7.5497899548918861e-8;
    pub const COS_C0: f32 = 4.166664568298827e-2;
    pub const COS_C1: f32 = -1.388731625493765e-3;
    pub const COS_C2: f32 = 2.443315711809948e-5;
    pub const SIN_C0: f32 = -1.6666654611e-1;
    pub const SIN_C1: f32 = 8.3321608736e-3;
    pub const SIN_C2: f32 = -1.9515295891e-4;
}
pub(crate) use consts::*;

/// Largest |angle| the polynomial cosine's Cody–Waite reduction handles
/// at the 1e-6 bound; beyond it the kernels fall back to libm.
pub const POLY_COS_MAX: f32 = 8192.0;

/// Range-reduced polynomial `cos` (the scalar form of the SIMD encoder
/// epilogue): |error| ≤ 1e-6 absolute vs libm for |x| ≤ [`POLY_COS_MAX`];
/// larger (or NaN) inputs take the libm fallback, so the function is
/// total and always bounded.
pub fn cos_poly(x: f32) -> f32 {
    let ax = x.abs();
    if ax.is_nan() || ax > POLY_COS_MAX {
        return x.cos();
    }
    let q = (ax * std::f32::consts::FRAC_2_PI).round();
    let qi = q as i32;
    let r = ((ax - q * PIO2_HI) - q * PIO2_MID) - q * PIO2_LO;
    let z = r * r;
    let pc = ((COS_C2 * z + COS_C1) * z + COS_C0) * (z * z) + (1.0 - 0.5 * z);
    let ps = (((SIN_C2 * z + SIN_C1) * z + SIN_C0) * z) * r + r;
    let v = if qi & 1 == 1 { ps } else { pc };
    if ((qi + 1) >> 1) & 1 == 1 {
        -v
    } else {
        v
    }
}

/// Dot product of two equal-length f32 slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::dot(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::dot(a, b) };
    }
    scalar::dot(a, b)
}

/// Dot of one query row against four model rows at once (each query
/// element loads once and feeds four accumulator chains).
#[inline]
pub fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    assert!(b0.len() == a.len() && b1.len() == a.len(), "dot4 length mismatch");
    assert!(b2.len() == a.len() && b3.len() == a.len(), "dot4 length mismatch");
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::dot4(a, b0, b1, b2, b3) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::dot4(a, b0, b1, b2, b3) };
    }
    scalar::dot4(a, b0, b1, b2, b3)
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::axpy(alpha, x, y) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::axpy(alpha, x, y) };
    }
    scalar::axpy(alpha, x, y)
}

/// Integer dot of two int8-valued i16 rows, accumulated in i32.
#[inline]
pub fn dot_i16(a: &[i16], b: &[i16]) -> i32 {
    assert_eq!(a.len(), b.len(), "dot_i16 length mismatch");
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::dot_i16(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::dot_i16(a, b) };
    }
    scalar::dot_i16(a, b)
}

/// Four-model-row variant of [`dot_i16`].
#[inline]
pub fn dot_i16_4(a: &[i16], b0: &[i16], b1: &[i16], b2: &[i16], b3: &[i16]) -> [i32; 4] {
    assert!(b0.len() == a.len() && b1.len() == a.len(), "dot_i16_4 length mismatch");
    assert!(b2.len() == a.len() && b3.len() == a.len(), "dot_i16_4 length mismatch");
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::dot_i16_4(a, b0, b1, b2, b3) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::dot_i16_4(a, b0, b1, b2, b3) };
    }
    scalar::dot_i16_4(a, b0, b1, b2, b3)
}

/// Hamming distance between two equal-length u64 word slices.
#[inline]
pub fn hamming(a: &[u64], b: &[u64]) -> u32 {
    assert_eq!(a.len(), b.len(), "hamming length mismatch");
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::hamming(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::hamming(a, b) };
    }
    scalar::hamming(a, b)
}

/// Maximum absolute value of a slice (0.0 for an empty slice).
#[inline]
pub fn max_abs(v: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::max_abs(v) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::max_abs(v) };
    }
    scalar::max_abs(v)
}

/// Symmetric int8 map `dst[i] = round(src[i] / scale).clamp(±127)`,
/// bit-identical to the scalar quantizer policy (`quant::quantize` at 8
/// bits). `src[i] / scale` must stay within i32 range — guaranteed when
/// `scale = max_abs(src) / 127`.
#[inline]
pub fn quantize_i16(src: &[f32], scale: f32, dst: &mut [i16]) {
    assert_eq!(src.len(), dst.len(), "quantize_i16 length mismatch");
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::quantize_i16(src, scale, dst) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::quantize_i16(src, scale, dst) };
    }
    scalar::quantize_i16(src, scale, dst)
}

/// Projection matrix `W` (F×D) repacked into contiguous column panels of
/// [`PANEL`] columns, k-major inside each panel (`panel[k*PANEL + lane]`),
/// zero-padded to a whole panel. Built once at `Encoder` construction so
/// the fused encode GEMM streams one contiguous block per output tile.
#[derive(Debug, Clone)]
pub struct PackedPanels {
    features: usize,
    dim: usize,
    data: Vec<f32>,
}

impl PackedPanels {
    /// Pack the columns of `w` (features × dim).
    pub fn pack_columns(w: &Matrix) -> Self {
        let (f, d) = (w.rows(), w.cols());
        let panels = d.div_ceil(PANEL);
        let mut data = vec![0.0f32; panels * f * PANEL];
        for p in 0..panels {
            let base = p * f * PANEL;
            let width = (d - p * PANEL).min(PANEL);
            for k in 0..f {
                let src = &w.row(k)[p * PANEL..p * PANEL + width];
                data[base + k * PANEL..base + k * PANEL + width].copy_from_slice(src);
            }
        }
        Self { features: f, dim: d, data }
    }

    #[inline]
    pub fn features(&self) -> usize {
        self.features
    }

    /// True (unpadded) output width.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn panels(&self) -> usize {
        self.dim.div_ceil(PANEL)
    }

    /// The packed panel stream (`panels() * features * PANEL` floats).
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// One panel's contiguous k-major block.
    #[inline]
    pub fn panel(&self, p: usize) -> &[f32] {
        let stride = self.features * PANEL;
        &self.data[p * stride..(p + 1) * stride]
    }
}

/// Fused encode of one query row: `out[j] = cos(<x, W[:,j]> + bias[j]) -
/// mu[j]`, GEMM epilogue applied on the register-resident panel tile.
/// The scalar path keeps libm `cos` (the reference); SIMD paths use the
/// range-reduced polynomial (≤ 1e-6 absolute from libm).
#[inline]
pub fn encode_row(x: &[f32], w: &PackedPanels, bias: &[f32], mu: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), w.features(), "encode_row: feature width mismatch");
    assert_eq!(out.len(), w.dim(), "encode_row: output width mismatch");
    assert_eq!(bias.len(), w.dim(), "encode_row: bias width mismatch");
    assert_eq!(mu.len(), w.dim(), "encode_row: mu width mismatch");
    #[cfg(target_arch = "x86_64")]
    if path() == Path::Avx2Fma {
        return unsafe { x86::encode_row(x, w, bias, mu, out) };
    }
    #[cfg(target_arch = "aarch64")]
    if path() == Path::Neon {
        return unsafe { neon::encode_row(x, w, bias, mu, out) };
    }
    scalar::encode_row(x, w, bias, mu, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn path_is_cached_and_labeled() {
        assert_eq!(path(), path());
        assert!(!path_label().is_empty());
    }

    #[test]
    fn cos_poly_tracks_libm_on_encoder_range() {
        let mut rng = SplitMix64::new(3);
        for _ in 0..20_000 {
            let x = ((rng.uniform() - 0.5) * 200.0) as f32;
            let want = (x as f64).cos() as f32;
            assert!((cos_poly(x) - want).abs() <= 1e-6, "x={x}");
        }
    }

    #[test]
    fn dispatched_dot_matches_scalar() {
        let mut rng = SplitMix64::new(7);
        for len in [0usize, 1, 7, 64, 65, 200] {
            let a = rng.normals_f32(len);
            let b = rng.normals_f32(len);
            let got = dot(&a, &b);
            let want = scalar::dot(&a, &b);
            assert!((got - want).abs() <= 1e-5 * (1.0 + want.abs()), "len={len}");
        }
    }

    #[test]
    fn packed_panels_layout() {
        let w = Matrix::from_vec(2, 10, (0..20).map(|v| v as f32).collect());
        let p = PackedPanels::pack_columns(&w);
        assert_eq!(p.panels(), 2);
        assert_eq!(p.data().len(), 2 * 2 * PANEL);
        // panel 0, k=1, lane 3 is w[1][3] = 13
        assert_eq!(p.panel(0)[PANEL + 3], 13.0);
        // panel 1 holds cols 8..10 then zero padding
        assert_eq!(p.panel(1)[0], 8.0);
        assert_eq!(p.panel(1)[2], 0.0);
    }

    #[test]
    fn encode_row_matches_two_pass_reference() {
        let mut rng = SplitMix64::new(11);
        for d in [1usize, 8, 13, 64, 65] {
            let f = 5;
            let w = Matrix::from_vec(f, d, rng.normals_f32(f * d));
            let x = rng.normals_f32(f);
            let bias = rng.normals_f32(d);
            let mu = rng.normals_f32(d);
            let packed = PackedPanels::pack_columns(&w);
            let mut out = vec![0.0f32; d];
            encode_row(&x, &packed, &bias, &mu, &mut out);
            for j in 0..d {
                let mut acc = 0.0f32;
                for (k, xv) in x.iter().enumerate() {
                    acc += xv * w.at(k, j);
                }
                let angle = acc + bias[j];
                let want = angle.cos() - mu[j];
                let tol = 2e-6 + 1e-5 * (1.0 + angle.abs());
                assert!((out[j] - want).abs() <= tol, "d={d} j={j}: {} vs {want}", out[j]);
            }
        }
    }
}

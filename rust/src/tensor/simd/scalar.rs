//! Portable reference kernels (the forced-scalar dispatch path).
//!
//! These define the semantics the SIMD paths are tested against: the
//! integer kernels must match bit-for-bit, the f32 reductions within
//! FMA/lane-reassociation tolerance, and [`encode_row`] keeps libm `cos`
//! so the scalar path stays the Python-parity reference. They are the
//! pre-SIMD hand-unrolled loops, moved here unchanged so auto-
//! vectorization still does its best when dispatch is forced scalar.

use super::{PANEL, PackedPanels};

/// Dot product with 4 independent accumulator chains.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = len / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc0 += a[i] * b[i];
        acc1 += a[i + 1] * b[i + 1];
        acc2 += a[i + 2] * b[i + 2];
        acc3 += a[i + 3] * b[i + 3];
    }
    let mut rest = 0.0f32;
    for i in chunks * 4..len {
        rest += a[i] * b[i];
    }
    acc0 + acc1 + acc2 + acc3 + rest
}

/// One query row against four model rows (each query element loads once).
pub fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    for (k, av) in a.iter().enumerate() {
        acc0 += av * b0[k];
        acc1 += av * b1[k];
        acc2 += av * b2[k];
        acc3 += av * b3[k];
    }
    [acc0, acc1, acc2, acc3]
}

/// `y += alpha * x` (the auto-vectorizable axpy form).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * *xv;
    }
}

/// Integer dot of two i16 rows in i32, 4-way unrolled.
pub fn dot_i16(a: &[i16], b: &[i16]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let k = i * 4;
        acc0 += a[k] as i32 * b[k] as i32;
        acc1 += a[k + 1] as i32 * b[k + 1] as i32;
        acc2 += a[k + 2] as i32 * b[k + 2] as i32;
        acc3 += a[k + 3] as i32 * b[k + 3] as i32;
    }
    let mut rest = 0i32;
    for k in chunks * 4..a.len() {
        rest += a[k] as i32 * b[k] as i32;
    }
    acc0 + acc1 + acc2 + acc3 + rest
}

/// One i16 query row against four model rows.
pub fn dot_i16_4(a: &[i16], b0: &[i16], b1: &[i16], b2: &[i16], b3: &[i16]) -> [i32; 4] {
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    for (k, av) in a.iter().enumerate() {
        let av = *av as i32;
        acc0 += av * b0[k] as i32;
        acc1 += av * b1[k] as i32;
        acc2 += av * b2[k] as i32;
        acc3 += av * b3[k] as i32;
    }
    [acc0, acc1, acc2, acc3]
}

/// Hamming distance between equal-length word slices, 4-way unrolled so
/// the popcounts retire on independent accumulators.
pub fn hamming(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut h0 = 0u32;
    let mut h1 = 0u32;
    let mut h2 = 0u32;
    let mut h3 = 0u32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let k = i * 4;
        h0 += (a[k] ^ b[k]).count_ones();
        h1 += (a[k + 1] ^ b[k + 1]).count_ones();
        h2 += (a[k + 2] ^ b[k + 2]).count_ones();
        h3 += (a[k + 3] ^ b[k + 3]).count_ones();
    }
    let mut rest = 0u32;
    for k in chunks * 4..a.len() {
        rest += (a[k] ^ b[k]).count_ones();
    }
    h0 + h1 + h2 + h3 + rest
}

/// Maximum absolute value (0.0 for an empty slice).
pub fn max_abs(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |acc, x| acc.max(x.abs()))
}

/// The symmetric int8 map: `round(v / scale)` clamped to ±127. This is
/// the level policy of `quant::quantize` at 8 bits; the SIMD paths must
/// reproduce it bit-for-bit (division, round-half-away, clamp order).
pub fn quantize_i16(src: &[f32], scale: f32, dst: &mut [i16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, v) in dst.iter_mut().zip(src.iter()) {
        *d = (v / scale).round().clamp(-127.0, 127.0) as i16;
    }
}

/// Fused encode of one row over the packed panels, with libm `cos`
/// (reference semantics: identical sum order to the old matmul + cos
/// two-pass, so forced-scalar output is bit-identical to the pre-fusion
/// encoder).
pub fn encode_row(x: &[f32], w: &PackedPanels, bias: &[f32], mu: &[f32], out: &mut [f32]) {
    let d = w.dim();
    for p in 0..w.panels() {
        let panel = w.panel(p);
        let col = p * PANEL;
        let width = (d - col).min(PANEL);
        let mut acc = [0.0f32; PANEL];
        for (k, xv) in x.iter().enumerate() {
            let prow = &panel[k * PANEL..(k + 1) * PANEL];
            for (av, pv) in acc.iter_mut().zip(prow.iter()) {
                *av += *xv * *pv;
            }
        }
        for lane in 0..width {
            let j = col + lane;
            out[j] = (acc[lane] + bias[j]).cos() - mu[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn dot_matches_simple_sum() {
        let mut rng = SplitMix64::new(11);
        for len in [0, 1, 3, 4, 7, 64, 129] {
            let a = rng.normals_f32(len);
            let b = rng.normals_f32(len);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-4, "len={len}");
        }
    }

    #[test]
    fn dot4_matches_four_dots() {
        let mut rng = SplitMix64::new(13);
        let a = rng.normals_f32(37);
        let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.normals_f32(37)).collect();
        let got = dot4(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
        for (j, row) in rows.iter().enumerate() {
            assert!((got[j] - dot(&a, row)).abs() < 1e-4);
        }
    }

    #[test]
    fn quantize_matches_scalar_policy() {
        let src = [1.0f32, -0.5, 0.247, -1.0, 0.0];
        let scale = 1.0 / 127.0;
        let mut dst = [0i16; 5];
        quantize_i16(&src, scale, &mut dst);
        assert_eq!(dst, [127, -64, 31, -127, 0]);
    }
}

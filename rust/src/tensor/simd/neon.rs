//! NEON kernels (aarch64). Selected by `super::path()` after runtime
//! detection; NEON is baseline on every aarch64 target we build for, so
//! these are effectively the default path on ARM servers and Apple
//! silicon. Structured as the 128-bit twin of the AVX2 module: same
//! loop shapes, same reduction identities, same polynomial constants.

#![allow(clippy::missing_safety_doc)] // crate-internal; callers are the detected dispatchers

use std::arch::aarch64::*;

use super::{COS_C0, COS_C1, COS_C2, PANEL, PIO2_HI, PIO2_LO, PIO2_MID, PackedPanels};
use super::{POLY_COS_MAX, SIN_C0, SIN_C1, SIN_C2};

#[target_feature(enable = "neon")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 8 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(ap.add(i + 4)), vld1q_f32(bp.add(i + 4)));
        i += 8;
    }
    if i + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
        i += 4;
    }
    let mut total = vaddvq_f32(vaddq_f32(acc0, acc1));
    while i < n {
        total += a[i] * b[i];
        i += 1;
    }
    total
}

#[target_feature(enable = "neon")]
pub unsafe fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let n = a.len();
    let ap = a.as_ptr();
    let mut c0 = vdupq_n_f32(0.0);
    let mut c1 = vdupq_n_f32(0.0);
    let mut c2 = vdupq_n_f32(0.0);
    let mut c3 = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 4 <= n {
        let av = vld1q_f32(ap.add(i));
        c0 = vfmaq_f32(c0, av, vld1q_f32(b0.as_ptr().add(i)));
        c1 = vfmaq_f32(c1, av, vld1q_f32(b1.as_ptr().add(i)));
        c2 = vfmaq_f32(c2, av, vld1q_f32(b2.as_ptr().add(i)));
        c3 = vfmaq_f32(c3, av, vld1q_f32(b3.as_ptr().add(i)));
        i += 4;
    }
    let mut out = [vaddvq_f32(c0), vaddvq_f32(c1), vaddvq_f32(c2), vaddvq_f32(c3)];
    while i < n {
        let av = a[i];
        out[0] += av * b0[i];
        out[1] += av * b1[i];
        out[2] += av * b2[i];
        out[3] += av * b3[i];
        i += 1;
    }
    out
}

#[target_feature(enable = "neon")]
pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0;
    while i + 4 <= n {
        let r = vfmaq_n_f32(vld1q_f32(yp.add(i)), vld1q_f32(xp.add(i)), alpha);
        vst1q_f32(yp.add(i), r);
        i += 4;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

#[target_feature(enable = "neon")]
pub unsafe fn dot_i16(a: &[i16], b: &[i16]) -> i32 {
    let n = a.len();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = vdupq_n_s32(0);
    let mut acc1 = vdupq_n_s32(0);
    let mut i = 0;
    while i + 8 <= n {
        let av = vld1q_s16(ap.add(i));
        let bv = vld1q_s16(bp.add(i));
        acc0 = vmlal_s16(acc0, vget_low_s16(av), vget_low_s16(bv));
        acc1 = vmlal_s16(acc1, vget_high_s16(av), vget_high_s16(bv));
        i += 8;
    }
    let mut total = vaddvq_s32(vaddq_s32(acc0, acc1));
    while i < n {
        total += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    total
}

#[target_feature(enable = "neon")]
pub unsafe fn dot_i16_4(a: &[i16], b0: &[i16], b1: &[i16], b2: &[i16], b3: &[i16]) -> [i32; 4] {
    let n = a.len();
    let ap = a.as_ptr();
    let mut c0 = vdupq_n_s32(0);
    let mut c1 = vdupq_n_s32(0);
    let mut c2 = vdupq_n_s32(0);
    let mut c3 = vdupq_n_s32(0);
    let mut i = 0;
    while i + 8 <= n {
        let av = vld1q_s16(ap.add(i));
        let (alo, ahi) = (vget_low_s16(av), vget_high_s16(av));
        let v0 = vld1q_s16(b0.as_ptr().add(i));
        let v1 = vld1q_s16(b1.as_ptr().add(i));
        let v2 = vld1q_s16(b2.as_ptr().add(i));
        let v3 = vld1q_s16(b3.as_ptr().add(i));
        c0 = vmlal_s16(vmlal_s16(c0, alo, vget_low_s16(v0)), ahi, vget_high_s16(v0));
        c1 = vmlal_s16(vmlal_s16(c1, alo, vget_low_s16(v1)), ahi, vget_high_s16(v1));
        c2 = vmlal_s16(vmlal_s16(c2, alo, vget_low_s16(v2)), ahi, vget_high_s16(v2));
        c3 = vmlal_s16(vmlal_s16(c3, alo, vget_low_s16(v3)), ahi, vget_high_s16(v3));
        i += 8;
    }
    let mut out = [vaddvq_s32(c0), vaddvq_s32(c1), vaddvq_s32(c2), vaddvq_s32(c3)];
    while i < n {
        let av = a[i] as i32;
        out[0] += av * b0[i] as i32;
        out[1] += av * b1[i] as i32;
        out[2] += av * b2[i] as i32;
        out[3] += av * b3[i] as i32;
        i += 1;
    }
    out
}

/// XOR + byte popcount (`vcnt`): each 16-byte chunk holds ≤ 128 set
/// bits, so the per-chunk byte-sum fits u8 and accumulates in u32.
#[target_feature(enable = "neon")]
pub unsafe fn hamming(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    let mut total = 0u32;
    let ap = a.as_ptr() as *const u8;
    let bp = b.as_ptr() as *const u8;
    let mut i = 0;
    while i + 2 <= n {
        let av = vld1q_u8(ap.add(i * 8));
        let bv = vld1q_u8(bp.add(i * 8));
        let cnt = vcntq_u8(veorq_u8(av, bv));
        total += vaddvq_u8(cnt) as u32;
        i += 2;
    }
    while i < n {
        total += (a[i] ^ b[i]).count_ones();
        i += 1;
    }
    total
}

#[target_feature(enable = "neon")]
pub unsafe fn max_abs(v: &[f32]) -> f32 {
    let n = v.len();
    let vp = v.as_ptr();
    let mut m = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 4 <= n {
        m = vmaxq_f32(m, vabsq_f32(vld1q_f32(vp.add(i))));
        i += 4;
    }
    let mut best = vmaxvq_f32(m);
    while i < n {
        best = best.max(v[i].abs());
        i += 1;
    }
    best
}

#[target_feature(enable = "neon")]
pub unsafe fn quantize_i16(src: &[f32], scale: f32, dst: &mut [i16]) {
    let n = src.len();
    let vscale = vdupq_n_f32(scale);
    let qmax = vdupq_n_s32(127);
    let qmin = vdupq_n_s32(-127);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut i = 0;
    while i + 8 <= n {
        let x0 = vdivq_f32(vld1q_f32(sp.add(i)), vscale);
        let x1 = vdivq_f32(vld1q_f32(sp.add(i + 4)), vscale);
        // vcvtaq rounds to nearest, ties away from zero — `f32::round`
        let q0 = vminq_s32(vmaxq_s32(vcvtaq_s32_f32(x0), qmin), qmax);
        let q1 = vminq_s32(vmaxq_s32(vcvtaq_s32_f32(x1), qmin), qmax);
        let narrowed = vcombine_s16(vqmovn_s32(q0), vqmovn_s32(q1));
        vst1q_s16(dp.add(i), narrowed);
        i += 8;
    }
    while i < n {
        dst[i] = (src[i] / scale).round().clamp(-127.0, 127.0) as i16;
        i += 1;
    }
}

/// 4-lane reduced-range polynomial cos (same constants and quadrant
/// logic as the AVX2 `cos_ps`).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn cos_q(x: float32x4_t) -> float32x4_t {
    let ax = vabsq_f32(x);
    let q = vrndnq_f32(vmulq_n_f32(ax, std::f32::consts::FRAC_2_PI));
    let qi = vcvtq_s32_f32(q);
    let r = vfmsq_f32(ax, q, vdupq_n_f32(PIO2_HI));
    let r = vfmsq_f32(r, q, vdupq_n_f32(PIO2_MID));
    let r = vfmsq_f32(r, q, vdupq_n_f32(PIO2_LO));
    let z = vmulq_f32(r, r);
    let pc = vfmaq_f32(vdupq_n_f32(COS_C1), vdupq_n_f32(COS_C2), z);
    let pc = vfmaq_f32(vdupq_n_f32(COS_C0), pc, z);
    let pc = vmulq_f32(pc, vmulq_f32(z, z));
    let base = vfmsq_f32(vdupq_n_f32(1.0), vdupq_n_f32(0.5), z);
    let pc = vaddq_f32(pc, base);
    let ps = vfmaq_f32(vdupq_n_f32(SIN_C1), vdupq_n_f32(SIN_C2), z);
    let ps = vfmaq_f32(vdupq_n_f32(SIN_C0), ps, z);
    let ps = vmulq_f32(ps, z);
    let ps = vfmaq_f32(r, ps, r);
    let odd = vtstq_s32(qi, vdupq_n_s32(1));
    let v = vbslq_f32(odd, ps, pc);
    let quad = vandq_u32(vreinterpretq_u32_s32(vaddq_s32(qi, vdupq_n_s32(1))), vdupq_n_u32(2));
    let sgn = vshlq_n_u32(quad, 30);
    vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(v), sgn))
}

/// `cos_q` guarded by its reduction domain: any lane with
/// |angle| > `POLY_COS_MAX` (or NaN) sends the 4-lane tile through libm
/// — never taken on sane inputs, keeps adversarial features bounded.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn cos_tile(v: float32x4_t) -> float32x4_t {
    let out_of_domain = vcagtq_f32(v, vdupq_n_f32(POLY_COS_MAX));
    let nan = vmvnq_u32(vceqq_f32(v, v));
    if vmaxvq_u32(vorrq_u32(out_of_domain, nan)) == 0 {
        return cos_q(v);
    }
    let mut a = [0.0f32; 4];
    vst1q_f32(a.as_mut_ptr(), v);
    for x in a.iter_mut() {
        *x = x.cos();
    }
    vld1q_f32(a.as_ptr())
}

/// One panel tile (8 columns = two 4-lane halves), 2-way k-unrolled.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn panel_dot(x: &[f32], panel: &[f32]) -> (float32x4_t, float32x4_t) {
    let f = x.len();
    let pp = panel.as_ptr();
    let mut lo0 = vdupq_n_f32(0.0);
    let mut hi0 = vdupq_n_f32(0.0);
    let mut lo1 = vdupq_n_f32(0.0);
    let mut hi1 = vdupq_n_f32(0.0);
    let mut k = 0;
    while k + 2 <= f {
        let x0 = x[k];
        let x1 = x[k + 1];
        lo0 = vfmaq_n_f32(lo0, vld1q_f32(pp.add(k * PANEL)), x0);
        hi0 = vfmaq_n_f32(hi0, vld1q_f32(pp.add(k * PANEL + 4)), x0);
        lo1 = vfmaq_n_f32(lo1, vld1q_f32(pp.add((k + 1) * PANEL)), x1);
        hi1 = vfmaq_n_f32(hi1, vld1q_f32(pp.add((k + 1) * PANEL + 4)), x1);
        k += 2;
    }
    if k < f {
        let x0 = x[k];
        lo0 = vfmaq_n_f32(lo0, vld1q_f32(pp.add(k * PANEL)), x0);
        hi0 = vfmaq_n_f32(hi0, vld1q_f32(pp.add(k * PANEL + 4)), x0);
    }
    (vaddq_f32(lo0, lo1), vaddq_f32(hi0, hi1))
}

#[target_feature(enable = "neon")]
pub unsafe fn encode_row(x: &[f32], w: &PackedPanels, bias: &[f32], mu: &[f32], out: &mut [f32]) {
    let d = w.dim();
    let full = d / PANEL;
    for p in 0..w.panels() {
        let (lo, hi) = panel_dot(x, w.panel(p));
        let col = p * PANEL;
        if p < full {
            let bp = bias.as_ptr().add(col);
            let mp = mu.as_ptr().add(col);
            let op = out.as_mut_ptr().add(col);
            let clo = cos_tile(vaddq_f32(lo, vld1q_f32(bp)));
            let chi = cos_tile(vaddq_f32(hi, vld1q_f32(bp.add(4))));
            let vlo = vsubq_f32(clo, vld1q_f32(mp));
            let vhi = vsubq_f32(chi, vld1q_f32(mp.add(4)));
            vst1q_f32(op, vlo);
            vst1q_f32(op.add(4), vhi);
        } else {
            let rem = d - col;
            let mut bb = [0.0f32; PANEL];
            let mut mm = [0.0f32; PANEL];
            let mut vv = [0.0f32; PANEL];
            bb[..rem].copy_from_slice(&bias[col..]);
            mm[..rem].copy_from_slice(&mu[col..]);
            let bbp = bb.as_ptr();
            let mmp = mm.as_ptr();
            let vvp = vv.as_mut_ptr();
            let clo = cos_tile(vaddq_f32(lo, vld1q_f32(bbp)));
            let chi = cos_tile(vaddq_f32(hi, vld1q_f32(bbp.add(4))));
            let vlo = vsubq_f32(clo, vld1q_f32(mmp));
            let vhi = vsubq_f32(chi, vld1q_f32(mmp.add(4)));
            vst1q_f32(vvp, vlo);
            vst1q_f32(vvp.add(4), vhi);
            out[col..].copy_from_slice(&vv[..rem]);
        }
    }
}

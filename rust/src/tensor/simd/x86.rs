//! AVX2 + FMA kernels (x86_64). Selected by `super::path()` only after
//! runtime detection of both features; every function here carries
//! `#[target_feature(enable = "avx2,fma")]` and must only be called from
//! the dispatch wrappers. Unaligned loads/stores throughout — the tensor
//! layer makes no alignment promises.

#![allow(clippy::missing_safety_doc)] // crate-internal; callers are the detected dispatchers

use std::arch::x86_64::*;

use super::{COS_C0, COS_C1, COS_C2, PANEL, PIO2_HI, PIO2_LO, PIO2_MID, PackedPanels};
use super::{POLY_COS_MAX, SIN_C0, SIN_C1, SIN_C2};

#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum_ps(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps(v, 1);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 0b01));
    _mm_cvtss_f32(s)
}

#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let lo = _mm256_castsi256_si128(v);
    let hi = _mm256_extracti128_si256(v, 1);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b10_11_00_01));
    _mm_cvtsi128_si32(s)
}

#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0;
    while i + 16 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        let (a1, b1) = (_mm256_loadu_ps(ap.add(i + 8)), _mm256_loadu_ps(bp.add(i + 8)));
        acc1 = _mm256_fmadd_ps(a1, b1, acc1);
        i += 16;
    }
    if i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc0);
        i += 8;
    }
    let mut total = hsum_ps(_mm256_add_ps(acc0, acc1));
    while i < n {
        total += a[i] * b[i];
        i += 1;
    }
    total
}

#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let n = a.len();
    let ap = a.as_ptr();
    let mut c0 = _mm256_setzero_ps();
    let mut c1 = _mm256_setzero_ps();
    let mut c2 = _mm256_setzero_ps();
    let mut c3 = _mm256_setzero_ps();
    let mut i = 0;
    while i + 8 <= n {
        let av = _mm256_loadu_ps(ap.add(i));
        c0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b0.as_ptr().add(i)), c0);
        c1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b1.as_ptr().add(i)), c1);
        c2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b2.as_ptr().add(i)), c2);
        c3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b3.as_ptr().add(i)), c3);
        i += 8;
    }
    let mut out = [hsum_ps(c0), hsum_ps(c1), hsum_ps(c2), hsum_ps(c3)];
    while i < n {
        let av = a[i];
        out[0] += av * b0[i];
        out[1] += av * b1[i];
        out[2] += av * b2[i];
        out[3] += av * b3[i];
        i += 1;
    }
    out
}

#[target_feature(enable = "avx2,fma")]
pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let va = _mm256_set1_ps(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut i = 0;
    while i + 8 <= n {
        let r = _mm256_fmadd_ps(va, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
        _mm256_storeu_ps(yp.add(i), r);
        i += 8;
    }
    while i < n {
        y[i] += alpha * x[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_i16(a: &[i16], b: &[i16]) -> i32 {
    let n = a.len();
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i + 16 <= n {
        let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
        let bv = _mm256_loadu_si256(bp.add(i) as *const __m256i);
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
        i += 16;
    }
    let mut total = hsum_epi32(acc);
    while i < n {
        total += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    total
}

#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot_i16_4(a: &[i16], b0: &[i16], b1: &[i16], b2: &[i16], b3: &[i16]) -> [i32; 4] {
    let n = a.len();
    let ap = a.as_ptr();
    let mut c0 = _mm256_setzero_si256();
    let mut c1 = _mm256_setzero_si256();
    let mut c2 = _mm256_setzero_si256();
    let mut c3 = _mm256_setzero_si256();
    let mut i = 0;
    while i + 16 <= n {
        let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
        let l0 = _mm256_loadu_si256(b0.as_ptr().add(i) as *const __m256i);
        let l1 = _mm256_loadu_si256(b1.as_ptr().add(i) as *const __m256i);
        let l2 = _mm256_loadu_si256(b2.as_ptr().add(i) as *const __m256i);
        let l3 = _mm256_loadu_si256(b3.as_ptr().add(i) as *const __m256i);
        c0 = _mm256_add_epi32(c0, _mm256_madd_epi16(av, l0));
        c1 = _mm256_add_epi32(c1, _mm256_madd_epi16(av, l1));
        c2 = _mm256_add_epi32(c2, _mm256_madd_epi16(av, l2));
        c3 = _mm256_add_epi32(c3, _mm256_madd_epi16(av, l3));
        i += 16;
    }
    let mut out = [hsum_epi32(c0), hsum_epi32(c1), hsum_epi32(c2), hsum_epi32(c3)];
    while i < n {
        let av = a[i] as i32;
        out[0] += av * b0[i] as i32;
        out[1] += av * b1[i] as i32;
        out[2] += av * b2[i] as i32;
        out[3] += av * b3[i] as i32;
        i += 1;
    }
    out
}

/// XOR + popcount over whole words via the nibble-LUT (`vpshufb`)
/// popcount, byte counts folded with `vpsadbw` into u64 lanes.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn hamming(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len();
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let low_mask = _mm256_set1_epi8(0x0F);
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i + 4 <= n {
        let av = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let bv = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        let x = _mm256_xor_si256(av, bv);
        let lo = _mm256_and_si256(x, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(x, 4), low_mask);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, _mm256_setzero_si256()));
        i += 4;
    }
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut total = (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32;
    while i < n {
        total += (a[i] ^ b[i]).count_ones();
        i += 1;
    }
    total
}

#[target_feature(enable = "avx2,fma")]
pub unsafe fn max_abs(v: &[f32]) -> f32 {
    let n = v.len();
    let mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
    let mut m = _mm256_setzero_ps();
    let vp = v.as_ptr();
    let mut i = 0;
    while i + 8 <= n {
        m = _mm256_max_ps(m, _mm256_and_ps(_mm256_loadu_ps(vp.add(i)), mask));
        i += 8;
    }
    let lo = _mm256_castps256_ps128(m);
    let hi = _mm256_extractf128_ps(m, 1);
    let s = _mm_max_ps(lo, hi);
    let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_max_ss(s, _mm_shuffle_ps(s, s, 0b01));
    let mut best = _mm_cvtss_f32(s);
    while i < n {
        best = best.max(v[i].abs());
        i += 1;
    }
    best
}

/// Round-half-away-from-zero to i32 (`f32::round` semantics), without
/// the double rounding a `trunc(x + 0.5)` trick suffers near values like
/// `0.5 − 2⁻²⁵`: round to nearest-even first (exact — no pre-addition),
/// then bump the exact halfway cases nearest-even sent toward zero back
/// out by ±1. `x − r` is exact (Sterbenz), so ties are detected exactly.
#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn round_away_epi32(x: __m256) -> __m256i {
    let sign = _mm256_and_ps(x, _mm256_castsi256_ps(_mm256_set1_epi32(i32::MIN)));
    let r = _mm256_round_ps(x, _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC);
    let d = _mm256_sub_ps(x, r);
    let half_signed = _mm256_or_ps(_mm256_set1_ps(0.5), sign);
    let one_signed = _mm256_or_ps(_mm256_set1_ps(1.0), sign);
    let tie_toward_zero = _mm256_cmp_ps(d, half_signed, _CMP_EQ_OQ);
    let r = _mm256_add_ps(r, _mm256_and_ps(tie_toward_zero, one_signed));
    _mm256_cvttps_epi32(r)
}

#[target_feature(enable = "avx2,fma")]
pub unsafe fn quantize_i16(src: &[f32], scale: f32, dst: &mut [i16]) {
    let n = src.len();
    let vscale = _mm256_set1_ps(scale);
    let qmax = _mm256_set1_epi32(127);
    let qmin = _mm256_set1_epi32(-127);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut i = 0;
    while i + 16 <= n {
        let x0 = _mm256_div_ps(_mm256_loadu_ps(sp.add(i)), vscale);
        let x1 = _mm256_div_ps(_mm256_loadu_ps(sp.add(i + 8)), vscale);
        let q0 = _mm256_min_epi32(_mm256_max_epi32(round_away_epi32(x0), qmin), qmax);
        let q1 = _mm256_min_epi32(_mm256_max_epi32(round_away_epi32(x1), qmin), qmax);
        // packs interleaves the 128-bit lanes; permute restores order.
        let packed = _mm256_packs_epi32(q0, q1);
        let fixed = _mm256_permute4x64_epi64(packed, 0b11_01_10_00);
        _mm256_storeu_si256(dp.add(i) as *mut __m256i, fixed);
        i += 16;
    }
    while i < n {
        dst[i] = (src[i] / scale).round().clamp(-127.0, 127.0) as i16;
        i += 1;
    }
}

/// Vector cos on the reduced-range polynomial (see `super::consts`):
/// quadrant from `round(|x|·2/π)`, Cody–Waite residual, sin/cos minimax
/// polys, blend + sign flip from the quadrant index.
#[target_feature(enable = "avx2,fma")]
unsafe fn cos_ps(x: __m256) -> __m256 {
    let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
    let ax = _mm256_and_ps(x, abs_mask);
    let t = _mm256_mul_ps(ax, _mm256_set1_ps(std::f32::consts::FRAC_2_PI));
    let q = _mm256_round_ps(t, _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC);
    let qi = _mm256_cvtps_epi32(q);
    let r = _mm256_fnmadd_ps(q, _mm256_set1_ps(PIO2_HI), ax);
    let r = _mm256_fnmadd_ps(q, _mm256_set1_ps(PIO2_MID), r);
    let r = _mm256_fnmadd_ps(q, _mm256_set1_ps(PIO2_LO), r);
    let z = _mm256_mul_ps(r, r);
    // cos(r) = ((C2 z + C1) z + C0) z² + (1 − z/2)
    let pc = _mm256_fmadd_ps(_mm256_set1_ps(COS_C2), z, _mm256_set1_ps(COS_C1));
    let pc = _mm256_fmadd_ps(pc, z, _mm256_set1_ps(COS_C0));
    let pc = _mm256_mul_ps(pc, _mm256_mul_ps(z, z));
    let base = _mm256_fnmadd_ps(_mm256_set1_ps(0.5), z, _mm256_set1_ps(1.0));
    let pc = _mm256_add_ps(pc, base);
    // sin(r) = ((S2 z + S1) z + S0) z r + r
    let ps = _mm256_fmadd_ps(_mm256_set1_ps(SIN_C2), z, _mm256_set1_ps(SIN_C1));
    let ps = _mm256_fmadd_ps(ps, z, _mm256_set1_ps(SIN_C0));
    let ps = _mm256_mul_ps(ps, z);
    let ps = _mm256_fmadd_ps(ps, r, r);
    // odd quadrant → sin; quadrants 1,2 (mod 4) → negate
    let one = _mm256_set1_epi32(1);
    let odd = _mm256_cmpeq_epi32(_mm256_and_si256(qi, one), one);
    let v = _mm256_blendv_ps(pc, ps, _mm256_castsi256_ps(odd));
    let quad = _mm256_and_si256(_mm256_add_epi32(qi, one), _mm256_set1_epi32(2));
    let sgn = _mm256_slli_epi32(quad, 30);
    _mm256_xor_ps(v, _mm256_castsi256_ps(sgn))
}

/// `cos_ps` guarded by its reduction domain: any lane with
/// |angle| > `POLY_COS_MAX` (or NaN) sends the whole tile through libm —
/// a branch that never fires on sane inputs but keeps adversarial client
/// features bounded and libm-accurate instead of polynomial garbage.
#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn cos_tile(v: __m256) -> __m256 {
    let ax = _mm256_and_ps(v, _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF)));
    let in_domain = _mm256_cmp_ps(ax, _mm256_set1_ps(POLY_COS_MAX), _CMP_LE_OQ);
    if _mm256_movemask_ps(in_domain) == 0xFF {
        return cos_ps(v);
    }
    let mut a = [0.0f32; PANEL];
    _mm256_storeu_ps(a.as_mut_ptr(), v);
    for x in a.iter_mut() {
        *x = x.cos();
    }
    _mm256_loadu_ps(a.as_ptr())
}

/// One panel's GEMM tile: 4 k-unrolled broadcast-FMA chains into one
/// 8-wide accumulator set.
#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn panel_dot(x: &[f32], panel: &[f32]) -> __m256 {
    let f = x.len();
    let pp = panel.as_ptr();
    let mut a0 = _mm256_setzero_ps();
    let mut a1 = _mm256_setzero_ps();
    let mut a2 = _mm256_setzero_ps();
    let mut a3 = _mm256_setzero_ps();
    let mut k = 0;
    while k + 4 <= f {
        let (x0, x1) = (_mm256_set1_ps(x[k]), _mm256_set1_ps(x[k + 1]));
        let (x2, x3) = (_mm256_set1_ps(x[k + 2]), _mm256_set1_ps(x[k + 3]));
        a0 = _mm256_fmadd_ps(x0, _mm256_loadu_ps(pp.add(k * PANEL)), a0);
        a1 = _mm256_fmadd_ps(x1, _mm256_loadu_ps(pp.add((k + 1) * PANEL)), a1);
        a2 = _mm256_fmadd_ps(x2, _mm256_loadu_ps(pp.add((k + 2) * PANEL)), a2);
        a3 = _mm256_fmadd_ps(x3, _mm256_loadu_ps(pp.add((k + 3) * PANEL)), a3);
        k += 4;
    }
    while k < f {
        a0 = _mm256_fmadd_ps(_mm256_set1_ps(x[k]), _mm256_loadu_ps(pp.add(k * PANEL)), a0);
        k += 1;
    }
    _mm256_add_ps(_mm256_add_ps(a0, a1), _mm256_add_ps(a2, a3))
}

/// Fused encode of one query row: panel GEMM, then the cos/bias/center
/// epilogue applied to the register-resident tile before the store.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn encode_row(x: &[f32], w: &PackedPanels, bias: &[f32], mu: &[f32], out: &mut [f32]) {
    let d = w.dim();
    let full = d / PANEL;
    for p in 0..w.panels() {
        let acc = panel_dot(x, w.panel(p));
        let col = p * PANEL;
        if p < full {
            let v = _mm256_add_ps(acc, _mm256_loadu_ps(bias.as_ptr().add(col)));
            let v = cos_tile(v);
            let v = _mm256_sub_ps(v, _mm256_loadu_ps(mu.as_ptr().add(col)));
            _mm256_storeu_ps(out.as_mut_ptr().add(col), v);
        } else {
            // partial tail panel: stage bias/mu/result through stack tiles
            let rem = d - col;
            let mut bb = [0.0f32; PANEL];
            let mut mm = [0.0f32; PANEL];
            let mut vv = [0.0f32; PANEL];
            bb[..rem].copy_from_slice(&bias[col..]);
            mm[..rem].copy_from_slice(&mu[col..]);
            let v = _mm256_add_ps(acc, _mm256_loadu_ps(bb.as_ptr()));
            let v = cos_tile(v);
            let v = _mm256_sub_ps(v, _mm256_loadu_ps(mm.as_ptr()));
            _mm256_storeu_ps(vv.as_mut_ptr(), v);
            out[col..].copy_from_slice(&vv[..rem]);
        }
    }
}

//! Matrix multiplication kernels for the native path.
//!
//! `matmul` (A·B) uses the cache-friendly i-k-j loop order: the inner loop
//! streams one row of B while accumulating into one row of C through the
//! dispatched [`simd::axpy`]. `matmul_nt` (A·Bᵀ) is the dot-product form
//! used by the similarity stage (both operands row-major along the shared
//! axis), register-blocked over 4 B-rows through [`simd::dot4`]. Both
//! parallelize over output rows.
//!
//! Model-side right-hand operands (bundles, profiles, prototypes) are
//! fixed across requests; [`NtPrepared`] hoists the transposed copy the
//! mid-width regime wants out of the per-batch path and into model/engine
//! state (`matmul_nt` alone still rebuilds it per call for ad-hoc
//! operands).

use super::simd;
use super::Matrix;
use crate::util::threadpool;

/// C = A (m×k) · B (k×n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    matmul_into(a, b, &mut out);
    out
}

/// [`matmul`] writing into a caller-owned output (resized in place; no
/// allocation once the scratch has reached the steady-state shape). The
/// accumulating axpy inner loop requires a zeroed output, so the reused
/// buffer is cleared first.
pub fn matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "matmul inner-dim mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    out.resize(m, n);
    out.data_mut().fill(0.0);
    let threads = threadpool::available_threads();
    let b_data = b.data();
    threadpool::parallel_rows(out.data_mut(), n.max(1), threads, |i, crow| {
        let arow = a.row(i);
        for kk in 0..k {
            let aik = arow[kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b_data[kk * n..(kk + 1) * n];
            // i-k-j: stream brow into crow (dispatched axpy).
            simd::axpy(aik, brow, crow);
        }
    });
}

/// Does the `matmul_nt` mid-width regime apply to a right-hand operand
/// with `n_rows` rows and shared width `k`? (Similarity against a few
/// dozen class rows: transposing B once makes the inner loop a contiguous
/// n-wide axpy over a cache-resident output row. Measured fastest for
/// 12..=64 target rows at k ≥ 256; below that the 4-row register-blocked
/// dot wins — EXPERIMENTS.md §Perf iterations 2–3.)
#[inline]
fn nt_prefers_transposed(n_rows: usize, k: usize) -> bool {
    (12..=64).contains(&n_rows) && k >= 256
}

/// Pre-built auxiliary state for a *fixed* `matmul_nt` right-hand side:
/// holds the transposed copy iff the mid-width regime applies to that
/// operand, so serving batches stop paying the per-call `transposed()`
/// allocation. Build once next to the operand (model/engine state) and
/// pass both to [`matmul_nt_with`].
#[derive(Debug, Clone, Default)]
pub struct NtPrepared {
    bt: Option<Matrix>,
}

impl NtPrepared {
    /// Prepare for the given operand (the future `b` of `matmul_nt`).
    pub fn for_operand(b: &Matrix) -> Self {
        let bt = nt_prefers_transposed(b.rows(), b.cols()).then(|| b.transposed());
        Self { bt }
    }

    /// Whether the transposed copy was materialized.
    pub fn is_transposed(&self) -> bool {
        self.bt.is_some()
    }
}

/// [`matmul_nt`] against a fixed operand with its [`NtPrepared`] state
/// (must have been built from this same `b`).
pub fn matmul_nt_with(a: &Matrix, b: &Matrix, prep: &NtPrepared) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    matmul_nt_with_into(a, b, prep, &mut out);
    out
}

/// [`matmul_nt_with`] writing into a caller-owned output (the serving
/// engines' form: the right-hand operand AND the output buffer are both
/// reused across batches, so the per-call GEMM allocates nothing at
/// steady state).
pub fn matmul_nt_with_into(a: &Matrix, b: &Matrix, prep: &NtPrepared, out: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "matmul_nt inner-dim mismatch");
    if let Some(bt) = &prep.bt {
        debug_assert_eq!((bt.rows(), bt.cols()), (b.cols(), b.rows()), "stale NtPrepared");
        matmul_into(a, bt, out);
        return;
    }
    matmul_nt_blocked_into(a, b, out);
}

/// C = A (m×k) · Bᵀ where B is (n×k): similarity shape.
///
/// Register-blocked over 4 B-rows via [`simd::dot4`]: each element of the
/// query row is loaded once and multiplied into 4 accumulator chains
/// (measured 2.6 → ~8 GFLOP/s single-core pre-SIMD on the serving shape;
/// EXPERIMENTS.md §Perf). Mid-width outputs switch to the transposed
/// i-k-j form (see [`NtPrepared`] to hoist that copy for fixed operands).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_nt inner-dim mismatch");
    if nt_prefers_transposed(b.rows(), a.cols()) {
        return matmul(a, &b.transposed());
    }
    matmul_nt_blocked(a, b)
}

/// [`matmul_nt`] writing into a caller-owned output, for right-hand
/// operands that change between calls (so [`NtPrepared`] cannot be
/// hoisted — e.g. the bundle matrix mid-refinement). Picks the same
/// regime as [`matmul_nt`]; the mid-width regime still pays the per-call
/// transposed copy, outside it the call is allocation-free once `out`
/// has reached its steady-state shape.
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols(), b.cols(), "matmul_nt inner-dim mismatch");
    if nt_prefers_transposed(b.rows(), a.cols()) {
        matmul_into(a, &b.transposed(), out);
        return;
    }
    matmul_nt_blocked_into(a, b, out);
}

fn matmul_nt_blocked(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    matmul_nt_blocked_into(a, b, &mut out);
    out
}

/// Register-blocked A·Bᵀ into a reused output. Every output element is
/// written unconditionally, so (unlike [`matmul_into`]) no clear of the
/// recycled buffer is needed.
fn matmul_nt_blocked_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let (m, n) = (a.rows(), b.rows());
    out.resize(m, n);
    let threads = threadpool::available_threads();
    threadpool::parallel_rows(out.data_mut(), n.max(1), threads, |i, crow| {
        let arow = a.row(i);
        let mut j = 0;
        while j + 4 <= n {
            let block = simd::dot4(arow, b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
            crow[j..j + 4].copy_from_slice(&block);
            j += 4;
        }
        for (jj, cv) in crow.iter_mut().enumerate().skip(j) {
            *cv = simd::dot(arow, b.row(jj));
        }
    });
}

/// C = Aᵀ (k×m)ᵀ·B ... i.e. A is (k×m), B is (k×n), C = AᵀB (m×n).
/// Used by bundling: Gᵀ(C×n)ᵀ · H(C×D).
///
/// Parallelized over output-row chunks: output row i is the B-row
/// combination Σ_k A[k,i]·B[k,:], so rows are independent and each worker
/// streams B once per owned row with the same contiguous n-wide axpy
/// inner loop the rank-1 form had. The strided A[k,i] reads touch one
/// column of A (k is the class count in the bundling shape — tiny).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shared-dim mismatch");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Matrix::zeros(m, n);
    if n == 0 || m == 0 {
        return out;
    }
    let threads = threadpool::available_threads();
    let b_data = b.data();
    threadpool::parallel_rows(out.data_mut(), n, threads, |i, crow| {
        for kk in 0..k {
            let aik = a.at(kk, i);
            if aik == 0.0 {
                continue;
            }
            let brow = &b_data[kk * n..(kk + 1) * n];
            simd::axpy(aik, brow, crow);
        }
    });
    out
}

/// Dot product over the first `len` elements (dispatched; see
/// [`simd::dot`] — kept under its historical name for call sites).
#[inline]
pub fn dot_unrolled(a: &[f32], b: &[f32], len: usize) -> f32 {
    simd::dot(&a[..len], &b[..len])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f32;
                for kk in 0..a.cols() {
                    acc += a.at(i, kk) * b.at(kk, j);
                }
                out.set(i, j, acc);
            }
        }
        out
    }

    fn rand_matrix(r: usize, c: usize, seed: u64) -> Matrix {
        let mut rng = SplitMix64::new(seed);
        Matrix::from_vec(r, c, rng.normals_f32(r * c))
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.cols(), b.cols());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        for (m, k, n, seed) in [(3, 5, 4, 1), (7, 13, 9, 2), (1, 1, 1, 3), (8, 64, 16, 4)] {
            let a = rand_matrix(m, k, seed);
            let b = rand_matrix(k, n, seed + 100);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-5);
        }
    }

    #[test]
    fn matmul_nt_matches_naive() {
        for (m, k, n, seed) in [(3, 5, 4, 1), (6, 33, 7, 2), (2, 128, 3, 5)] {
            let a = rand_matrix(m, k, seed);
            let b = rand_matrix(n, k, seed + 7);
            assert_close(&matmul_nt(&a, &b), &naive(&a, &b.transposed()), 1e-5);
        }
    }

    #[test]
    fn matmul_nt_with_matches_plain_in_both_regimes() {
        // (n, k) pairs straddling the mid-width boundary
        for (m, k, n, seed) in [(3, 300, 26, 1), (2, 300, 7, 2), (4, 64, 26, 3)] {
            let a = rand_matrix(m, k, seed);
            let b = rand_matrix(n, k, seed + 31);
            let prep = NtPrepared::for_operand(&b);
            assert_eq!(prep.is_transposed(), (12..=64).contains(&n) && k >= 256);
            assert_close(&matmul_nt_with(&a, &b, &prep), &matmul_nt(&a, &b), 1e-5);
        }
    }

    #[test]
    fn matmul_tn_matches_naive() {
        for (k, m, n, seed) in [(5, 3, 4, 1), (26, 7, 50, 2)] {
            let a = rand_matrix(k, m, seed);
            let b = rand_matrix(k, n, seed + 9);
            assert_close(&matmul_tn(&a, &b), &naive(&a.transposed(), &b), 1e-5);
        }
    }

    #[test]
    fn dot_unrolled_matches_simple() {
        let mut rng = SplitMix64::new(11);
        for len in [0, 1, 3, 4, 7, 64, 129] {
            let a = rng.normals_f32(len);
            let b = rng.normals_f32(len);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot_unrolled(&a, &b, len);
            assert!((got - want).abs() < 1e-4, "len={len}: {got} vs {want}");
        }
    }
}

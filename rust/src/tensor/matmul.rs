//! Matrix multiplication kernels for the native path.
//!
//! `matmul` (A·B) uses the cache-friendly i-k-j loop order: the inner loop
//! streams one row of B while accumulating into one row of C, which the
//! compiler auto-vectorizes. `matmul_nt` (A·Bᵀ) is the dot-product form
//! used by the similarity stage (both operands row-major along the shared
//! axis), unrolled into four independent accumulators to break the FP add
//! dependency chain. Both parallelize over output rows.

use super::Matrix;
use crate::util::threadpool;

/// C = A (m×k) · B (k×n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul inner-dim mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Matrix::zeros(m, n);
    let threads = threadpool::available_threads();
    let b_data = b.data();
    threadpool::parallel_rows(out.data_mut(), n, threads, |i, crow| {
        let arow = a.row(i);
        for kk in 0..k {
            let aik = arow[kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b_data[kk * n..(kk + 1) * n];
            // i-k-j: stream brow into crow (auto-vectorized axpy).
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * *bv;
            }
        }
    });
    out
}

/// C = A (m×k) · Bᵀ where B is (n×k): similarity shape.
///
/// Register-blocked over 4 B-rows: each element of the query row is
/// loaded once and multiplied into 4 accumulators, quadrupling arithmetic
/// intensity vs the naive one-row-at-a-time dot (measured 2.6 → ~8
/// GFLOP/s single-core on the serving shape; EXPERIMENTS.md §Perf).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "matmul_nt inner-dim mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    // Mid-width-output regime (similarity against a few dozen class
    // rows): transposing B once makes the inner loop a contiguous n-wide
    // axpy over a cache-resident output row — the i-k-j form. Measured
    // fastest for 12..=64 target rows (C=26: 11.8 → 9.1 ms at the Table II
    // shape); below that the axpy is too short to vectorize well and the
    // 4-row register-blocked path wins (n=7: 3.4 ms vs 6.1 ms) — §Perf
    // iterations 2–3.
    if (12..=64).contains(&n) && k >= 256 {
        return matmul(a, &b.transposed());
    }
    let mut out = Matrix::zeros(m, n);
    let threads = threadpool::available_threads();
    threadpool::parallel_rows(out.data_mut(), n, threads, |i, crow| {
        let arow = a.row(i);
        let mut j = 0;
        while j + 4 <= n {
            let (b0, b1, b2, b3) = (b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
            let mut acc0 = 0.0f32;
            let mut acc1 = 0.0f32;
            let mut acc2 = 0.0f32;
            let mut acc3 = 0.0f32;
            for kk in 0..k {
                let av = arow[kk];
                acc0 += av * b0[kk];
                acc1 += av * b1[kk];
                acc2 += av * b2[kk];
                acc3 += av * b3[kk];
            }
            crow[j] = acc0;
            crow[j + 1] = acc1;
            crow[j + 2] = acc2;
            crow[j + 3] = acc3;
            j += 4;
        }
        for (jj, cv) in crow.iter_mut().enumerate().skip(j) {
            *cv = dot_unrolled(arow, b.row(jj), k);
        }
    });
    out
}

/// C = Aᵀ (k×m)ᵀ·B ... i.e. A is (k×m), B is (k×n), C = AᵀB (m×n).
/// Used by bundling: Gᵀ(C×n)ᵀ · H(C×D).
///
/// Parallelized over output-row chunks: output row i is the B-row
/// combination Σ_k A[k,i]·B[k,:], so rows are independent and each worker
/// streams B once per owned row with the same contiguous n-wide axpy
/// inner loop the rank-1 form had. The strided A[k,i] reads touch one
/// column of A (k is the class count in the bundling shape — tiny).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shared-dim mismatch");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Matrix::zeros(m, n);
    if n == 0 || m == 0 {
        return out;
    }
    let threads = threadpool::available_threads();
    let b_data = b.data();
    threadpool::parallel_rows(out.data_mut(), n, threads, |i, crow| {
        for kk in 0..k {
            let aik = a.at(kk, i);
            if aik == 0.0 {
                continue;
            }
            let brow = &b_data[kk * n..(kk + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * *bv;
            }
        }
    });
    out
}

/// Dot product with 4-way unrolling (independent accumulators).
#[inline]
pub fn dot_unrolled(a: &[f32], b: &[f32], len: usize) -> f32 {
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = len / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc0 += a[i] * b[i];
        acc1 += a[i + 1] * b[i + 1];
        acc2 += a[i + 2] * b[i + 2];
        acc3 += a[i + 3] * b[i + 3];
    }
    let mut rest = 0.0f32;
    for i in chunks * 4..len {
        rest += a[i] * b[i];
    }
    acc0 + acc1 + acc2 + acc3 + rest
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f32;
                for kk in 0..a.cols() {
                    acc += a.at(i, kk) * b.at(kk, j);
                }
                out.set(i, j, acc);
            }
        }
        out
    }

    fn rand_matrix(r: usize, c: usize, seed: u64) -> Matrix {
        let mut rng = SplitMix64::new(seed);
        Matrix::from_vec(r, c, rng.normals_f32(r * c))
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.cols(), b.cols());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        for (m, k, n, seed) in [(3, 5, 4, 1), (7, 13, 9, 2), (1, 1, 1, 3), (8, 64, 16, 4)] {
            let a = rand_matrix(m, k, seed);
            let b = rand_matrix(k, n, seed + 100);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-5);
        }
    }

    #[test]
    fn matmul_nt_matches_naive() {
        for (m, k, n, seed) in [(3, 5, 4, 1), (6, 33, 7, 2), (2, 128, 3, 5)] {
            let a = rand_matrix(m, k, seed);
            let b = rand_matrix(n, k, seed + 7);
            assert_close(&matmul_nt(&a, &b), &naive(&a, &b.transposed()), 1e-5);
        }
    }

    #[test]
    fn matmul_tn_matches_naive() {
        for (k, m, n, seed) in [(5, 3, 4, 1), (26, 7, 50, 2)] {
            let a = rand_matrix(k, m, seed);
            let b = rand_matrix(k, n, seed + 9);
            assert_close(&matmul_tn(&a, &b), &naive(&a.transposed(), &b), 1e-5);
        }
    }

    #[test]
    fn dot_unrolled_matches_simple() {
        let mut rng = SplitMix64::new(11);
        for len in [0, 1, 3, 4, 7, 64, 129] {
            let a = rng.normals_f32(len);
            let b = rng.normals_f32(len);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot_unrolled(&a, &b, len);
            assert!((got - want).abs() < 1e-4, "len={len}: {got} vs {want}");
        }
    }
}

//! Element/row-wise tensor operations shared across the pipeline.

use super::Matrix;

/// L2 norm of a slice.
#[inline]
pub fn norm(v: &[f32]) -> f32 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
}

/// In-place row L2-normalization with a zero guard (matches the Python
/// `_normalize_rows`: rows with norm < 1e-12 are left ~zero, not NaN).
pub fn normalize_rows(m: &mut Matrix) {
    let cols = m.cols();
    for r in 0..m.rows() {
        let row = m.row_mut(r);
        let n = norm(row).max(1e-12);
        let inv = 1.0 / n;
        for v in row.iter_mut() {
            *v *= inv;
        }
        let _ = cols;
    }
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * *xv;
    }
}

/// Subtract a row vector from every row (centering).
pub fn sub_row_inplace(m: &mut Matrix, v: &[f32]) {
    assert_eq!(m.cols(), v.len());
    for r in 0..m.rows() {
        for (mv, vv) in m.row_mut(r).iter_mut().zip(v.iter()) {
            *mv -= *vv;
        }
    }
}

/// Column means computed in f64 (mirrors numpy's mean for our parity).
pub fn col_means(m: &Matrix) -> Vec<f32> {
    let mut acc = vec![0.0f64; m.cols()];
    for r in 0..m.rows() {
        for (a, v) in acc.iter_mut().zip(m.row(r)) {
            *a += *v as f64;
        }
    }
    let n = m.rows().max(1) as f64;
    acc.into_iter().map(|a| (a / n) as f32).collect()
}

/// Index of the maximum element (first on ties).
#[inline]
pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, x) in v.iter().enumerate() {
        if *x > bv {
            bv = *x;
            best = i;
        }
    }
    best
}

/// Index of the minimum element (first on ties).
#[inline]
pub fn argmin(v: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::INFINITY;
    for (i, x) in v.iter().enumerate() {
        if *x < bv {
            bv = *x;
            best = i;
        }
    }
    best
}

/// Per-row L2 norms.
pub fn row_norms(m: &Matrix) -> Vec<f32> {
    (0..m.rows()).map(|r| norm(m.row(r))).collect()
}

/// Per-row squared L2 norms.
pub fn row_sqnorms(m: &Matrix) -> Vec<f32> {
    (0..m.rows()).map(|r| m.row(r).iter().map(|v| v * v).sum()).collect()
}

/// [`row_sqnorms`] into a reused buffer (cleared and refilled; capacity
/// persists across calls).
pub fn row_sqnorms_into(m: &Matrix, out: &mut Vec<f32>) {
    out.clear();
    out.extend((0..m.rows()).map(|r| m.row(r).iter().map(|v| v * v).sum::<f32>()));
}

/// All-pairs squared distances via the expansion
/// `|a_i − p_c|² = |a_i|² − 2·a_i·p_c + |p_c|²`: one GEMM instead of a
/// B·C·n scalar loop, with the tiny negative residues the expansion can
/// produce clamped to zero. `p_sqnorms` must be `row_sqnorms(p)` —
/// callers that store `p` precompute it once at model build.
pub fn pairwise_sqdists_pre(a: &Matrix, p: &Matrix, p_sqnorms: &[f32]) -> Matrix {
    assert_eq!(a.cols(), p.cols(), "pairwise_sqdists width mismatch");
    assert_eq!(p.rows(), p_sqnorms.len(), "p_sqnorms length mismatch");
    sqdist_epilogue(super::matmul_nt(a, p), a, p_sqnorms)
}

/// [`pairwise_sqdists_pre`] against a fixed profile operand with its
/// [`super::NtPrepared`] state (model/engine-resident, so the mid-width
/// GEMM regime stops re-transposing `p` every batch).
pub fn pairwise_sqdists_prepared(
    a: &Matrix,
    p: &Matrix,
    p_sqnorms: &[f32],
    prep: &super::NtPrepared,
) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    let mut a_sq = Vec::new();
    pairwise_sqdists_prepared_into(a, p, p_sqnorms, prep, &mut a_sq, &mut out);
    out
}

/// [`pairwise_sqdists_prepared`] writing into caller-owned scratch: `a_sq`
/// holds the per-query `|a_i|²` terms and `out` the (B, C) distances,
/// both reused across batches so the fused decode allocates nothing at
/// steady state.
pub fn pairwise_sqdists_prepared_into(
    a: &Matrix,
    p: &Matrix,
    p_sqnorms: &[f32],
    prep: &super::NtPrepared,
    a_sq: &mut Vec<f32>,
    out: &mut Matrix,
) {
    assert_eq!(a.cols(), p.cols(), "pairwise_sqdists width mismatch");
    assert_eq!(p.rows(), p_sqnorms.len(), "p_sqnorms length mismatch");
    super::matmul_nt_with_into(a, p, prep, out);
    sqdist_epilogue_into(out, a, p_sqnorms, a_sq);
}

fn sqdist_epilogue(mut out: Matrix, a: &Matrix, p_sqnorms: &[f32]) -> Matrix {
    let mut a_sq = Vec::new();
    sqdist_epilogue_into(&mut out, a, p_sqnorms, &mut a_sq);
    out
}

fn sqdist_epilogue_into(out: &mut Matrix, a: &Matrix, p_sqnorms: &[f32], a_sq: &mut Vec<f32>) {
    row_sqnorms_into(a, a_sq);
    for (i, &asq) in a_sq.iter().enumerate() {
        for (v, &psq) in out.row_mut(i).iter_mut().zip(p_sqnorms) {
            *v = (asq - 2.0 * *v + psq).max(0.0);
        }
    }
}

/// [`pairwise_sqdists_pre`] with the `|p_c|²` terms computed on the fly.
pub fn pairwise_sqdists(a: &Matrix, p: &Matrix) -> Matrix {
    pairwise_sqdists_pre(a, p, &row_sqnorms(p))
}

/// Squared Euclidean distance.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_and_normalize() {
        let mut m = Matrix::from_vec(2, 2, vec![3.0, 4.0, 0.0, 0.0]);
        normalize_rows(&mut m);
        assert!((m.at(0, 0) - 0.6).abs() < 1e-6);
        assert!((m.at(0, 1) - 0.8).abs() < 1e-6);
        // zero row stays finite
        assert!(m.row(1).iter().all(|v| v.is_finite()));
    }

    #[test]
    fn axpy_works() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 21.0]);
    }

    #[test]
    fn centering() {
        let mut m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let mu = col_means(&m);
        assert_eq!(mu, vec![2.0, 3.0]);
        sub_row_inplace(&mut m, &mu);
        assert_eq!(m.data(), &[-1.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn argmax_argmin_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmin(&[1.0, 1.0, 0.5]), 2);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn sqdist_works() {
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn pairwise_sqdists_matches_scalar_loop() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(21);
        let a = Matrix::from_vec(4, 6, rng.normals_f32(24));
        let p = Matrix::from_vec(3, 6, rng.normals_f32(18));
        let d = pairwise_sqdists(&a, &p);
        for i in 0..4 {
            for c in 0..3 {
                let want = sqdist(a.row(i), p.row(c));
                assert!((d.at(i, c) - want).abs() < 1e-4, "({i},{c})");
            }
        }
    }

    #[test]
    fn pairwise_sqdists_clamps_self_distance_to_zero() {
        let a = Matrix::from_vec(1, 3, vec![0.3, -0.7, 0.11]);
        let d = pairwise_sqdists(&a, &a);
        assert_eq!(d.at(0, 0), 0.0);
    }
}

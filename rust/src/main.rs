//! `loghd` binary: thin wrapper over [`loghd::cli`].

fn main() {
    loghd::cli::main_entry();
}

//! Cosine similarity (paper Eq. 1) — the native twin of the L1
//! `activation` Pallas kernel: queries vs a matrix of pre-normalized rows.

use crate::tensor::{self, Matrix};

/// Cosine activations of raw (unnormalized) query rows against
/// pre-normalized rows `m`: returns (B, n) with entries
/// `<enc_i/|enc_i|, m_j>` — identical semantics to the Pallas kernel and
/// `ref.activation_ref`.
pub fn activations(enc: &Matrix, m: &Matrix) -> Matrix {
    assert_eq!(enc.cols(), m.cols(), "dimension mismatch");
    scale_by_query_norm(tensor::matmul_nt(enc, m), enc)
}

/// [`activations`] into a reused output matrix, for model-side operands
/// that *change* between calls (mid-refinement bundles — a fixed operand
/// should use [`activations_with_into`] instead). Same regime selection
/// and float behavior as [`activations`].
pub fn activations_into(enc: &Matrix, m: &Matrix, out: &mut Matrix) {
    assert_eq!(enc.cols(), m.cols(), "dimension mismatch");
    tensor::matmul_nt_into(enc, m, out);
    scale_rows_by_query_norm(out, enc);
}

/// [`activations`] against a *fixed* model-side operand with its
/// [`tensor::NtPrepared`] state: serving engines build the prepared form
/// once (model load) instead of re-transposing `m` every batch in the
/// mid-width GEMM regime.
pub fn activations_with(enc: &Matrix, m: &Matrix, prep: &tensor::NtPrepared) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    activations_with_into(enc, m, prep, &mut out);
    out
}

/// [`activations_with`] into a reused output matrix — the zero-allocation
/// serving form (both the prepared operand and the output scratch persist
/// across batches).
pub fn activations_with_into(
    enc: &Matrix,
    m: &Matrix,
    prep: &tensor::NtPrepared,
    out: &mut Matrix,
) {
    assert_eq!(enc.cols(), m.cols(), "dimension mismatch");
    tensor::matmul_nt_with_into(enc, m, prep, out);
    scale_rows_by_query_norm(out, enc);
}

fn scale_by_query_norm(mut dots: Matrix, enc: &Matrix) -> Matrix {
    scale_rows_by_query_norm(&mut dots, enc);
    dots
}

fn scale_rows_by_query_norm(dots: &mut Matrix, enc: &Matrix) {
    for i in 0..enc.rows() {
        let qn = tensor::norm(enc.row(i)).max(1e-12);
        let inv = 1.0 / qn;
        for v in dots.row_mut(i) {
            *v *= inv;
        }
    }
}

/// Cosine similarity between two raw vectors.
pub fn cosine_one(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let na = tensor::norm(a).max(1e-12);
    let nb = tensor::norm(b).max(1e-12);
    tensor::dot_unrolled(a, b, a.len()) / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::normalize_rows;
    use crate::util::rng::SplitMix64;

    #[test]
    fn matches_manual_cosine() {
        let mut rng = SplitMix64::new(3);
        let enc = Matrix::from_vec(4, 16, rng.normals_f32(64));
        let mut m = Matrix::from_vec(3, 16, rng.normals_f32(48));
        normalize_rows(&mut m);
        let a = activations(&enc, &m);
        for i in 0..4 {
            for j in 0..3 {
                let want = cosine_one(enc.row(i), m.row(j));
                assert!((a.at(i, j) - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn bounded_by_one() {
        let mut rng = SplitMix64::new(9);
        let enc = Matrix::from_vec(8, 32, rng.normals_f32(256));
        let mut m = Matrix::from_vec(5, 32, rng.normals_f32(160));
        normalize_rows(&mut m);
        let a = activations(&enc, &m);
        assert!(a.data().iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }

    #[test]
    fn prepared_matches_plain_in_both_gemm_regimes() {
        let mut rng = SplitMix64::new(21);
        for (n, d) in [(7usize, 300usize), (26, 300), (26, 64)] {
            let enc = Matrix::from_vec(3, d, rng.normals_f32(3 * d));
            let mut m = Matrix::from_vec(n, d, rng.normals_f32(n * d));
            normalize_rows(&mut m);
            let prep = crate::tensor::NtPrepared::for_operand(&m);
            let a = activations(&enc, &m);
            let b = activations_with(&enc, &m, &prep);
            for (x, y) in a.data().iter().zip(b.data()) {
                assert!((x - y).abs() < 1e-5, "n={n} d={d}");
            }
        }
    }

    #[test]
    fn zero_query_is_finite() {
        let enc = Matrix::zeros(1, 8);
        let mut m = Matrix::from_vec(2, 8, SplitMix64::new(1).normals_f32(16));
        normalize_rows(&mut m);
        let a = activations(&enc, &m);
        assert!(a.data().iter().all(|v| v.is_finite()));
    }
}

//! Class-prototype training (Algorithm 1 step 1) and the OnlineHD-style
//! perceptron refinement used for the conventional baseline — the native
//! twin of `python/compile/trainer.py::{train_prototypes,
//! refine_conventional}` (same update rule and shuffle stream; floating
//! point accumulation order differs, so parity is statistical, not
//! bitwise).

use crate::hd::similarity::activations;
use crate::tensor::{self, Matrix};
use crate::util::rng::SplitMix64;

/// H_c = normalize(sum of encoded class samples), accumulated in f64.
pub fn train_prototypes(enc: &Matrix, y: &[i32], classes: usize) -> Matrix {
    assert_eq!(enc.rows(), y.len());
    let d = enc.cols();
    let mut acc = vec![0.0f64; classes * d];
    for (i, &cls) in y.iter().enumerate() {
        let row = enc.row(i);
        let dst = &mut acc[cls as usize * d..(cls as usize + 1) * d];
        for (a, v) in dst.iter_mut().zip(row) {
            *a += *v as f64;
        }
    }
    let mut h = Matrix::from_vec(classes, d, acc.into_iter().map(|v| v as f32).collect());
    tensor::normalize_rows(&mut h);
    h
}

/// OnlineHD-style passes: for each misclassified sample, pull its class
/// prototype toward the (unit-norm) encoding and push the confused one
/// away, weighted by (1 - score). Rows re-normalized at the end.
pub fn refine_conventional(
    h: &Matrix,
    enc: &Matrix,
    y: &[i32],
    epochs: usize,
    eta: f32,
    seed: u64,
    batch: usize,
) -> Matrix {
    let d = enc.cols();
    let mut hwork = h.clone();
    // unit-norm encodings once
    let mut encn = enc.clone();
    tensor::normalize_rows(&mut encn);
    let mut rng = SplitMix64::new(seed);
    let mut idx: Vec<usize> = (0..y.len()).collect();
    for _ in 0..epochs {
        rng.shuffle(&mut idx);
        for chunk in idx.chunks(batch) {
            let mut hn = hwork.clone();
            tensor::normalize_rows(&mut hn);
            let xb = gather_rows(enc, chunk);
            let scores = activations(&xb, &hn);
            for (bi, &si) in chunk.iter().enumerate() {
                let srow = scores.row(bi);
                let pred = tensor::argmax(srow);
                let truth = y[si] as usize;
                if pred == truth {
                    continue;
                }
                let e = encn.row(si).to_vec();
                let up = eta * (1.0 - srow[truth]);
                tensor::axpy(up, &e, hwork.row_mut(truth));
                let down = eta * (1.0 - srow[pred]);
                tensor::axpy(-down, &e, hwork.row_mut(pred));
            }
        }
    }
    tensor::normalize_rows(&mut hwork);
    let _ = d;
    hwork
}

/// Gather a batch of rows by index.
pub fn gather_rows(m: &Matrix, idx: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(idx.len(), m.cols());
    for (i, &si) in idx.iter().enumerate() {
        out.row_mut(i).copy_from_slice(m.row(si));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn toy() -> (Matrix, Vec<i32>) {
        // Three well-separated clusters in 8-d encoding space.
        let mut rng = SplitMix64::new(1);
        let mut enc = Matrix::zeros(30, 8);
        let mut y = Vec::new();
        for i in 0..30 {
            let cls = i % 3;
            y.push(cls as i32);
            let row = enc.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                let base = if j == cls * 2 { 2.0 } else { 0.0 };
                *v = base + 0.1 * rng.normal() as f32;
            }
        }
        (enc, y)
    }

    #[test]
    fn prototypes_unit_and_aligned() {
        let (enc, y) = toy();
        let h = train_prototypes(&enc, &y, 3);
        for r in 0..3 {
            assert!((tensor::norm(h.row(r)) - 1.0).abs() < 1e-5);
        }
        // each prototype points at its cluster's dominant axis
        for cls in 0..3 {
            assert_eq!(tensor::argmax(h.row(cls)), cls * 2);
        }
    }

    #[test]
    fn prototype_classification_works() {
        let (enc, y) = toy();
        let h = train_prototypes(&enc, &y, 3);
        let scores = activations(&enc, &h);
        let mut hits = 0;
        for i in 0..enc.rows() {
            if tensor::argmax(scores.row(i)) == y[i] as usize {
                hits += 1;
            }
        }
        assert_eq!(hits, 30);
    }

    #[test]
    fn refinement_does_not_break_separable_case() {
        let (enc, y) = toy();
        let h = train_prototypes(&enc, &y, 3);
        let h2 = refine_conventional(&h, &enc, &y, 2, 0.05, 42, 8);
        let scores = activations(&enc, &h2);
        for i in 0..enc.rows() {
            assert_eq!(tensor::argmax(scores.row(i)), y[i] as usize);
        }
    }

    #[test]
    fn gather_rows_picks_rows() {
        let m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = gather_rows(&m, &[2, 0]);
        assert_eq!(g.row(0), &[5., 6.]);
        assert_eq!(g.row(1), &[1., 2.]);
    }
}

//! Core HDC substrate: cosine similarity and class-prototype training
//! (paper §III-A / Algorithm 1 step 1, plus the OnlineHD-style baseline
//! refinement used to keep the conventional model strong).

pub mod prototype;
pub mod similarity;

pub use prototype::{refine_conventional, train_prototypes};
pub use similarity::{activations, cosine_one};

//! Micro-benchmark harness (criterion is not vendored offline).
//!
//! Used by the `benches/` targets (`harness = false`): warmup, timed
//! iterations, robust statistics, and a small CSV writer for the figure
//! harnesses' outputs under `results/`.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Timing statistics over the measured iterations (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub p99: f64,
    pub min: f64,
}

/// Run `f` for `warmup` + `iters` iterations and report stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let q = |p: f64| samples[(((samples.len() - 1) as f64) * p).round() as usize];
    Stats { iters, mean, median: q(0.5), p99: q(0.99), min: samples[0] }
}

impl Stats {
    pub fn format_line(&self, label: &str) -> String {
        format!(
            "{label:<48} mean {:>10.3?}  median {:>10.3?}  p99 {:>10.3?}  ({} iters)",
            std::time::Duration::from_secs_f64(self.mean),
            std::time::Duration::from_secs_f64(self.median),
            std::time::Duration::from_secs_f64(self.p99),
            self.iters
        )
    }
}

/// Simple CSV writer for the figure harnesses: creates parent dirs.
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &str) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{header}")?;
        Ok(Self { file })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        writeln!(self.file, "{}", fields.join(","))
    }
}

/// Render an ASCII curve chart (one line per series) — the quick-look
/// output the bench targets print next to the CSVs.
pub fn ascii_chart(title: &str, xs: &[f64], series: &[(String, Vec<f64>)]) -> String {
    let mut out = format!("## {title}\n");
    out.push_str(&format!(
        "{:<28} {}\n",
        "series \\ x",
        xs.iter().map(|x| format!("{x:>7.2}")).collect::<Vec<_>>().join(" ")
    ));
    for (name, ys) in series {
        out.push_str(&format!(
            "{:<28} {}\n",
            name,
            ys.iter().map(|y| format!("{y:>7.3}")).collect::<Vec<_>>().join(" ")
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let stats = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(stats.iters, 5);
        assert!(stats.min <= stats.median && stats.median <= stats.p99);
    }

    #[test]
    fn csv_writer_writes(
    ) {
        let dir = std::env::temp_dir().join("loghd_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, "a,b").unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn ascii_chart_contains_series() {
        let c = ascii_chart("t", &[0.0, 1.0], &[("s".into(), vec![0.5, 0.25])]);
        assert!(c.contains("## t"));
        assert!(c.contains("0.500"));
    }
}

//! A counting global allocator for allocation-regression tests and the
//! serving benches.
//!
//! Install it with `#[global_allocator]` in a test or bench binary,
//! snapshot [`CountingAlloc::allocs`] / [`CountingAlloc::bytes`] around
//! a measured region, and assert on (or report) the deltas. Counters
//! are process-wide and monotonic — they count every allocation on
//! every thread, including worker replicas, which is exactly what a
//! "zero allocations per request in steady state" claim needs.
//!
//! Deallocations are deliberately not tracked: the regression gate is
//! about allocator *traffic* on the hot path, not leaks.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Delegates to [`System`], counting calls and bytes.
pub struct CountingAlloc {
    allocs: AtomicU64,
    bytes: AtomicU64,
}

impl CountingAlloc {
    pub const fn new() -> Self {
        Self { allocs: AtomicU64::new(0), bytes: AtomicU64::new(0) }
    }

    /// Total allocation calls (alloc + alloc_zeroed + realloc) so far.
    pub fn allocs(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }

    /// Total bytes requested so far.
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn count(&self, size: usize) {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(size as u64, Ordering::Relaxed);
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: pure delegation to `System`; the counters are lock-free
// atomics and never allocate.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.count(layout.size());
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.count(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.count(new_size);
        System.realloc(ptr, layout, new_size)
    }
}

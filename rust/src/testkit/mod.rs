//! Deterministic test fixtures + golden-artifact conformance tooling.
//!
//! Two things every conformance suite in this repo needs:
//!
//! - **Miniature datasets** — scaled-down Table I workloads that keep
//!   the full generators' leading PRNG draws (same class means/scales,
//!   fewer samples), so fixtures are deterministic across processes,
//!   platforms, and thread counts. [`mini`] has one preset per dataset;
//!   [`scaled_dataset`] takes explicit sample caps (the campaign engine
//!   builds its workloads through it).
//! - **Golden comparison** — [`golden`] checks a produced JSON document
//!   against a committed golden with *subtree* semantics: every field
//!   the golden pins must exist and match (exact for strings / bools /
//!   integer-valued numbers under the default tolerance, relative
//!   tolerance for floats), while fields the golden does not mention are
//!   unconstrained — so goldens can pin the stable core of an artifact
//!   (schema, solver tables, grids) without freezing measured values.
//!   `LOGHD_BLESS=1` rewrites the golden from the produced document.
//!
//! Plus one perf-side tool: [`alloc_counter`], a counting global
//! allocator the allocation-regression test and the serving benches
//! install to measure allocator traffic per request.

pub mod alloc_counter;
pub mod golden;

use anyhow::{Context, Result};

use crate::data::{self, Dataset};

/// A Table I dataset scaled to explicit sample counts (same geometry —
/// identical leading PRNG draws — fewer samples).
pub fn scaled_dataset(name: &str, n_train: usize, n_test: usize) -> Result<Dataset> {
    let spec = data::spec(name).with_context(|| format!("unknown dataset '{name}'"))?;
    Ok(data::generate_scaled(spec, spec.n_train.min(n_train), spec.n_test.min(n_test)))
}

/// The miniature preset for `name`: big enough to train meaningfully,
/// small enough for tight test loops.
pub fn mini(name: &str) -> Result<Dataset> {
    let (n_train, n_test) = match name {
        "page" => (400, 150),
        "pamap2" => (600, 200),
        "ucihar" => (800, 250),
        "isolet" => (1000, 300),
        _ => (500, 200),
    };
    scaled_dataset(name, n_train, n_test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mini_datasets_are_deterministic_and_scaled() {
        let a = mini("page").unwrap();
        let b = mini("page").unwrap();
        assert_eq!(a.x_train.data(), b.x_train.data());
        assert_eq!(a.y_test, b.y_test);
        assert_eq!(a.x_train.rows(), 400);
        assert_eq!(a.x_test.rows(), 150);
        assert_eq!(a.spec.classes, data::spec("page").unwrap().classes);
    }

    #[test]
    fn scaled_dataset_caps_at_spec_size() {
        let ds = scaled_dataset("page", 10_000_000, 10_000_000).unwrap();
        let spec = data::spec("page").unwrap();
        assert_eq!(ds.x_train.rows(), spec.n_train);
        assert_eq!(ds.x_test.rows(), spec.n_test);
        assert!(scaled_dataset("nope", 10, 10).is_err());
    }
}

//! Golden-artifact JSON comparison (subtree semantics, bless support).
//!
//! A golden pins the *stable core* of an artifact: every field the
//! golden mentions must exist in the produced document and match; extra
//! produced fields are unconstrained. Numbers compare exactly at
//! `float_tol = 0.0` (the packed / integer paths) and within a relative
//! band otherwise (the f32 paths). Dotted paths in `ignore` (e.g.
//! `"meta"` or `"cells.3.acc_mean"`) are skipped entirely — array
//! indices appear as numeric path segments.
//!
//! Re-bless a golden after an intentional artifact change with
//! `LOGHD_BLESS=1 cargo test …` — the check then *writes* the produced
//! document to the golden path and passes; review the diff like any
//! other code change.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// Comparison options.
#[derive(Debug, Clone, Default)]
pub struct GoldenOptions {
    /// Relative tolerance for numbers: pass when
    /// `|got − want| ≤ tol · (1 + |want|)`. `0.0` means exact.
    pub float_tol: f64,
    /// Dotted paths to skip (prefix match on whole segments).
    pub ignore: Vec<String>,
}

impl GoldenOptions {
    pub fn exact() -> Self {
        Self::default()
    }

    pub fn with_tol(float_tol: f64) -> Self {
        Self { float_tol, ignore: Vec::new() }
    }

    pub fn ignoring(mut self, path: &str) -> Self {
        self.ignore.push(path.to_string());
        self
    }

    fn is_ignored(&self, path: &str) -> bool {
        self.ignore.iter().any(|ig| {
            path == ig || path.strip_prefix(ig.as_str()).is_some_and(|rest| rest.starts_with('.'))
        })
    }
}

/// All mismatches between `got` and the golden subtree `want`, as
/// human-readable `path: problem` lines. Empty means conformant.
pub fn diffs(got: &Value, want: &Value, opts: &GoldenOptions) -> Vec<String> {
    let mut out = Vec::new();
    walk(got, want, opts, "$", &mut out);
    out
}

fn walk(got: &Value, want: &Value, opts: &GoldenOptions, path: &str, out: &mut Vec<String>) {
    let rel = path.strip_prefix("$.").unwrap_or("");
    if opts.is_ignored(rel) {
        return;
    }
    match (got, want) {
        (Value::Object(_), Value::Object(want_fields)) => {
            for (key, want_val) in want_fields {
                match got.get(key) {
                    Some(got_val) => {
                        walk(got_val, want_val, opts, &format!("{path}.{key}"), out)
                    }
                    None => out.push(format!("{path}.{key}: missing from produced document")),
                }
            }
        }
        (Value::Array(got_items), Value::Array(want_items)) => {
            if got_items.len() != want_items.len() {
                out.push(format!(
                    "{path}: array length {} != golden {}",
                    got_items.len(),
                    want_items.len()
                ));
                return;
            }
            for (i, (g, w)) in got_items.iter().zip(want_items).enumerate() {
                walk(g, w, opts, &format!("{path}.{i}"), out);
            }
        }
        (Value::Number(g), Value::Number(w)) => {
            let ok = if opts.float_tol == 0.0 {
                g == w
            } else {
                (g - w).abs() <= opts.float_tol * (1.0 + w.abs())
            };
            if !ok {
                out.push(format!("{path}: {g} != golden {w} (tol {})", opts.float_tol));
            }
        }
        (g, w) if g == w => {}
        (g, w) => out.push(format!(
            "{path}: {} != golden {}",
            json::to_string(g),
            json::to_string(w)
        )),
    }
}

/// `true` when re-blessing was requested via `LOGHD_BLESS=1`.
pub fn blessing() -> bool {
    matches!(std::env::var("LOGHD_BLESS").as_deref(), Ok(v) if !v.is_empty() && v != "0")
}

/// Check `got` against the golden file at `path`. Under `LOGHD_BLESS=1`
/// the produced document is written to `path` instead (and the check
/// passes). Errors list every mismatching path.
pub fn check_file(path: impl AsRef<Path>, got: &Value, opts: &GoldenOptions) -> Result<()> {
    let path = path.as_ref();
    if blessing() {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, json::to_string_pretty(got) + "\n")
            .with_context(|| format!("blessing golden {}", path.display()))?;
        eprintln!("blessed golden {}", path.display());
        return Ok(());
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading golden {} (LOGHD_BLESS=1 to create)", path.display()))?;
    let want = json::parse(&text)
        .map_err(|e| anyhow::Error::msg(format!("golden {}: {e}", path.display())))?;
    let problems = diffs(got, &want, opts);
    if !problems.is_empty() {
        bail!(
            "golden mismatch vs {} ({} problems):\n  {}",
            path.display(),
            problems.len(),
            problems.join("\n  ")
        );
    }
    Ok(())
}

/// A copy of `v` with the named top-level object keys removed — for
/// comparing two produced documents while excluding run metadata.
pub fn without_keys(v: Value, keys: &[&str]) -> Value {
    match v {
        Value::Object(fields) => Value::Object(
            fields.into_iter().filter(|(k, _)| !keys.contains(&k.as_str())).collect(),
        ),
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(text: &str) -> Value {
        json::parse(text).unwrap()
    }

    #[test]
    fn subtree_semantics_allow_extra_produced_fields() {
        let got = doc(r#"{"a": 1, "b": {"x": 2, "y": 3}, "extra": true}"#);
        let want = doc(r#"{"a": 1, "b": {"x": 2}}"#);
        assert!(diffs(&got, &want, &GoldenOptions::exact()).is_empty());
        // but golden fields must exist
        let want2 = doc(r#"{"a": 1, "missing": 0}"#);
        let d = diffs(&got, &want2, &GoldenOptions::exact());
        assert_eq!(d.len(), 1);
        assert!(d[0].contains("missing"));
    }

    #[test]
    fn exact_vs_tolerant_numbers() {
        let got = doc("{\"v\": 0.500001}");
        let want = doc("{\"v\": 0.5}");
        assert_eq!(diffs(&got, &want, &GoldenOptions::exact()).len(), 1);
        assert!(diffs(&got, &want, &GoldenOptions::with_tol(1e-3)).is_empty());
        assert_eq!(diffs(&got, &want, &GoldenOptions::with_tol(1e-9)).len(), 1);
    }

    #[test]
    fn arrays_compare_elementwise_and_by_length() {
        let got = doc("[1, 2, 3]");
        assert!(diffs(&got, &doc("[1, 2, 3]"), &GoldenOptions::exact()).is_empty());
        assert_eq!(diffs(&got, &doc("[1, 2]"), &GoldenOptions::exact()).len(), 1);
        let d = diffs(&got, &doc("[1, 9, 3]"), &GoldenOptions::exact());
        assert_eq!(d.len(), 1);
        assert!(d[0].contains("$.1"), "{d:?}");
    }

    #[test]
    fn ignore_paths_skip_subtrees() {
        let got = doc(r#"{"meta": {"elapsed": 1.0}, "cells": [{"a": 1}]}"#);
        let want = doc(r#"{"meta": {"elapsed": 2.0}, "cells": [{"a": 1}]}"#);
        let opts = GoldenOptions::exact().ignoring("meta");
        assert!(diffs(&got, &want, &opts).is_empty());
        let opts2 = GoldenOptions::exact().ignoring("me");
        assert_eq!(diffs(&got, &want, &opts2).len(), 1, "prefix must match whole segments");
        let opts3 = GoldenOptions::exact().ignoring("cells.0.a");
        let want3 = doc(r#"{"cells": [{"a": 99}]}"#);
        assert!(diffs(&got, &want3, &opts3).is_empty());
    }

    #[test]
    fn type_mismatch_reports() {
        let d = diffs(&doc("{\"v\": \"s\"}"), &doc("{\"v\": 1}"), &GoldenOptions::exact());
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn without_keys_strips_top_level() {
        let v = doc(r#"{"a": 1, "meta": {"t": 2}}"#);
        let stripped = without_keys(v, &["meta"]);
        assert!(stripped.get("meta").is_none());
        assert!(stripped.get("a").is_some());
    }

    #[test]
    fn check_file_round_trip_with_bless() {
        let dir = std::env::temp_dir().join("loghd_golden_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("g.json");
        let got = doc(r#"{"a": 1, "b": [0.5]}"#);
        std::fs::write(&path, json::to_string_pretty(&got)).unwrap();
        check_file(&path, &got, &GoldenOptions::exact()).unwrap();
        let other = doc(r#"{"a": 2, "b": [0.5]}"#);
        assert!(check_file(&path, &other, &GoldenOptions::exact()).is_err());
        assert!(check_file(dir.join("absent.json"), &got, &GoldenOptions::exact()).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}

//! PJRT runtime: load the AOT artifacts and execute them from Rust.
//!
//! This is the request-path bridge of the three-layer architecture:
//! `python -m compile.aot` lowered the L2 graphs (which call the L1 Pallas
//! kernels) to HLO *text*; here we parse that text
//! (`HloModuleProto::from_text_file` — the text parser reassigns the
//! 64-bit instruction ids jax ≥ 0.5 emits that xla_extension 0.5.1
//! rejects), compile it on the PJRT CPU client, and execute with model
//! tensors as runtime inputs. Because the tensors are inputs rather than
//! baked constants, the coordinator can inject stored-state bit flips and
//! re-serve without recompiling.

pub mod artifact;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Matrix;
use artifact::{EntrySpec, Manifest};

/// A compiled entry point.
pub struct LoadedEntry {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: one PJRT CPU client + all compiled entries of one bundle
/// + the bundle's model tensors.
pub struct PjrtRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    entries: HashMap<String, LoadedEntry>,
    tensors: HashMap<String, Matrix>,
}

/// Outputs of one entry execution.
#[derive(Debug, Clone)]
pub struct Outputs {
    pub f32s: Vec<(String, Vec<usize>, Vec<f32>)>,
    pub i32s: Vec<(String, Vec<usize>, Vec<i32>)>,
}

impl Outputs {
    pub fn f32_named(&self, name: &str) -> Option<&(String, Vec<usize>, Vec<f32>)> {
        self.f32s.iter().find(|(n, _, _)| n == name)
    }

    pub fn i32_named(&self, name: &str) -> Option<&(String, Vec<usize>, Vec<i32>)> {
        self.i32s.iter().find(|(n, _, _)| n == name)
    }
}

impl PjrtRuntime {
    /// Load an artifact bundle directory and compile every entry.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut entries = HashMap::new();
        for spec in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", spec.hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling entry '{}'", spec.name))?;
            entries.insert(spec.name.clone(), LoadedEntry { spec: spec.clone(), exe });
        }
        let mut tensors = HashMap::new();
        for (name, path) in &manifest.tensors {
            let t = artifact::read_lht(path)?;
            if let Ok(m) = t.to_matrix() {
                tensors.insert(name.clone(), m);
            }
        }
        Ok(Self { manifest, client, entries, tensors })
    }

    pub fn entry_names(&self) -> Vec<&str> {
        self.entries.keys().map(String::as_str).collect()
    }

    /// Model tensor by manifest name (f32 rank<=2 only).
    pub fn tensor(&self, name: &str) -> Option<&Matrix> {
        self.tensors.get(name)
    }

    /// Replace a model tensor (fault injection / model swap). Shape must
    /// match the original.
    pub fn set_tensor(&mut self, name: &str, m: Matrix) -> Result<()> {
        match self.tensors.get(name) {
            Some(old) if old.rows() == m.rows() && old.cols() == m.cols() => {
                self.tensors.insert(name.to_string(), m);
                Ok(())
            }
            Some(old) => bail!(
                "shape mismatch for '{name}': {}x{} vs {}x{}",
                m.rows(),
                m.cols(),
                old.rows(),
                old.cols()
            ),
            None => bail!("unknown tensor '{name}'"),
        }
    }

    fn literal_for(&self, name: &str, shape: &[usize], batch_x: Option<&Matrix>) -> Result<xla::Literal> {
        let m: &Matrix = if name == "x" {
            batch_x.context("entry expects input 'x' but no batch was provided")?
        } else {
            self.tensors
                .get(name)
                .with_context(|| format!("input tensor '{name}' not loaded"))?
        };
        let want: usize = shape.iter().product();
        if m.rows() * m.cols() != want {
            bail!("tensor '{name}' has {} values, entry wants {want}", m.rows() * m.cols());
        }
        let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
        Ok(xla::Literal::vec1(m.data()).reshape(&dims)?)
    }

    /// Execute an entry. `batch_x` supplies the `x` input (padded to the
    /// entry's fixed batch); model tensors come from the bundle.
    pub fn execute(&self, entry: &str, batch_x: Option<&Matrix>) -> Result<Outputs> {
        let loaded = self.entries.get(entry).with_context(|| format!("no entry '{entry}'"))?;
        let mut inputs = Vec::with_capacity(loaded.spec.inputs.len());
        for (name, shape, _dtype) in &loaded.spec.inputs {
            inputs.push(self.literal_for(name, shape, batch_x)?);
        }
        let result = loaded.exe.execute::<xla::Literal>(&inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = lit.to_tuple()?;
        if parts.len() != loaded.spec.outputs.len() {
            bail!(
                "entry '{entry}': {} outputs, manifest declares {}",
                parts.len(),
                loaded.spec.outputs.len()
            );
        }
        let mut out = Outputs { f32s: Vec::new(), i32s: Vec::new() };
        for (part, (name, shape, dtype)) in parts.into_iter().zip(&loaded.spec.outputs) {
            match dtype.as_str() {
                "f32" => out.f32s.push((name.clone(), shape.clone(), part.to_vec::<f32>()?)),
                "i32" => out.i32s.push((name.clone(), shape.clone(), part.to_vec::<i32>()?)),
                other => bail!("unsupported output dtype {other}"),
            }
        }
        Ok(out)
    }

    /// Batched inference helper: run `entry` over all rows of `x`
    /// (padding the final partial batch), returning per-row labels from
    /// the output named `labels`.
    pub fn infer_labels(&self, entry: &str, x: &Matrix) -> Result<Vec<i32>> {
        let batch = self.manifest.batch;
        let mut labels = Vec::with_capacity(x.rows());
        let mut lo = 0;
        while lo < x.rows() {
            let hi = (lo + batch).min(x.rows());
            let mut xb = Matrix::zeros(batch, x.cols());
            for (bi, r) in (lo..hi).enumerate() {
                xb.row_mut(bi).copy_from_slice(x.row(r));
            }
            let out = self.execute(entry, Some(&xb))?;
            let (_, _, batch_labels) =
                out.i32_named("labels").context("entry has no 'labels' output")?;
            labels.extend_from_slice(&batch_labels[..hi - lo]);
            lo = hi;
        }
        Ok(labels)
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }
}

//! Artifact loading: the LHT tensor format (twin of
//! `python/compile/lht.py`) and the `manifest.json` emitted by
//! `python -m compile.aot`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::Matrix;
use crate::util::json;

const MAGIC: &[u8; 4] = b"LHT1";

/// A loaded LHT tensor.
#[derive(Debug, Clone)]
pub enum LhtTensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    U8 { dims: Vec<usize>, data: Vec<u8> },
}

impl LhtTensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            LhtTensor::F32 { dims, .. } | LhtTensor::I32 { dims, .. } | LhtTensor::U8 { dims, .. } => dims,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            LhtTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            LhtTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// View a rank-2 f32 tensor as a Matrix (copies).
    pub fn to_matrix(&self) -> Result<Matrix> {
        let dims = self.dims();
        let (rows, cols) = match dims.len() {
            1 => (1usize, dims[0]),
            2 => (dims[0], dims[1]),
            _ => bail!("expected rank<=2 tensor, got {dims:?}"),
        };
        Ok(Matrix::from_vec(rows, cols, self.as_f32()?.to_vec()))
    }
}

/// Read an LHT file.
pub fn read_lht(path: &Path) -> Result<LhtTensor> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() < 6 || &bytes[..4] != MAGIC {
        bail!("{}: bad LHT magic", path.display());
    }
    let dtype = bytes[4];
    let ndim = bytes[5] as usize;
    let header = 6 + 4 * ndim;
    if bytes.len() < header {
        bail!("{}: truncated header", path.display());
    }
    let mut dims = Vec::with_capacity(ndim);
    for i in 0..ndim {
        let off = 6 + 4 * i;
        dims.push(u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize);
    }
    let count: usize = dims.iter().product();
    let payload = &bytes[header..];
    let need = |elt: usize| -> Result<()> {
        if payload.len() != count * elt {
            bail!("{}: payload {} != {}x{}", path.display(), payload.len(), count, elt);
        }
        Ok(())
    };
    Ok(match dtype {
        0 => {
            need(4)?;
            let data = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            LhtTensor::F32 { dims, data }
        }
        1 => {
            need(4)?;
            let data = payload
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            LhtTensor::I32 { dims, data }
        }
        2 => {
            need(1)?;
            LhtTensor::U8 { dims, data: payload.to_vec() }
        }
        other => bail!("{}: unknown dtype {other}", path.display()),
    })
}

/// Write an LHT file (f32 matrix form — the shapes Rust exports).
pub fn write_lht_f32(path: &Path, dims: &[usize], data: &[f32]) -> Result<()> {
    let count: usize = dims.iter().product();
    if count != data.len() {
        bail!("dims {dims:?} do not match {} values", data.len());
    }
    let mut out = Vec::with_capacity(6 + 4 * dims.len() + 4 * data.len());
    out.extend_from_slice(MAGIC);
    out.push(0u8);
    out.push(dims.len() as u8);
    for d in dims {
        out.extend_from_slice(&(*d as u32).to_le_bytes());
    }
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// One lowered entry point (HLO file + declared I/O shapes).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<(String, Vec<usize>, String)>,
    pub outputs: Vec<(String, Vec<usize>, String)>,
}

/// A parsed artifact bundle directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub name: String,
    pub dataset: String,
    pub d: usize,
    pub k: u32,
    pub n: usize,
    pub classes: usize,
    pub features: usize,
    pub batch: usize,
    pub clean_acc_conventional: f64,
    pub clean_acc_loghd: f64,
    pub entries: Vec<EntrySpec>,
    pub tensors: Vec<(String, PathBuf)>,
}

fn io_list(v: &json::Value) -> Result<Vec<(String, Vec<usize>, String)>> {
    let mut out = Vec::new();
    for item in v.as_array().context("expected io array")? {
        let parts = item.as_array().context("expected [name, shape, dtype]")?;
        let name = parts[0].as_str().context("io name")?.to_string();
        let shape = parts[1]
            .as_array()
            .context("io shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = parts[2].as_str().context("io dtype")?.to_string();
        out.push((name, shape, dtype));
    }
    Ok(out)
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let cfg = v.get("config").context("manifest.config")?;
        let get_usize = |key: &str| -> Result<usize> {
            cfg.get(key).and_then(json::Value::as_usize).with_context(|| format!("config.{key}"))
        };
        let mut entries = Vec::new();
        for e in v.get("entries").and_then(json::Value::as_array).context("entries")? {
            entries.push(EntrySpec {
                name: e.get("name").and_then(json::Value::as_str).context("entry.name")?.into(),
                hlo_path: dir.join(e.get("hlo").and_then(json::Value::as_str).context("entry.hlo")?),
                inputs: io_list(e.get("inputs").context("entry.inputs")?)?,
                outputs: io_list(e.get("outputs").context("entry.outputs")?)?,
            });
        }
        let mut tensors = Vec::new();
        if let Some(json::Value::Object(fields)) = v.get("tensors").cloned() {
            for (name, file) in fields {
                tensors.push((name, dir.join(file.as_str().context("tensor path")?)));
            }
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            name: cfg.get("name").and_then(json::Value::as_str).context("config.name")?.into(),
            dataset: cfg.get("dataset").and_then(json::Value::as_str).context("config.dataset")?.into(),
            d: get_usize("D")?,
            k: get_usize("k")? as u32,
            n: get_usize("n")?,
            classes: get_usize("C")?,
            features: get_usize("F")?,
            batch: get_usize("batch")?,
            clean_acc_conventional: v
                .get_path(&["clean_accuracy", "conventional"])
                .and_then(json::Value::as_f64)
                .unwrap_or(0.0),
            clean_acc_loghd: v
                .get_path(&["clean_accuracy", "loghd"])
                .and_then(json::Value::as_f64)
                .unwrap_or(0.0),
            entries,
            tensors,
        })
    }

    pub fn entry(&self, name: &str) -> Option<&EntrySpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// The registry-facing identity of this bundle.
    pub fn card(&self) -> ModelCard {
        ModelCard {
            dir: self.dir.clone(),
            kind: "aot-bundle".to_string(),
            classes: self.classes,
            d: self.d,
            features: self.features,
            // AOT bundles carry no model.json and cannot hold a cascade
            // calibration; `--cascade` admission rejects them.
            cascade_threshold: None,
        }
    }

    /// Load a named tensor from the bundle.
    pub fn tensor(&self, name: &str) -> Result<LhtTensor> {
        let (_, path) = self
            .tensors
            .iter()
            .find(|(n, _)| n == name)
            .with_context(|| format!("tensor '{name}' not in manifest"))?;
        read_lht(path)
    }
}

/// The registry-facing identity of an artifact directory: just enough
/// metadata to admit, route, and hot-swap a serving tenant without loading
/// its tensors. Covers both native artifacts (`model.json`, kinds
/// `native-loghd` / `native-conventional`) and Python AOT bundles
/// (`manifest.json`, kind `aot-bundle`).
#[derive(Debug, Clone)]
pub struct ModelCard {
    pub dir: PathBuf,
    pub kind: String,
    pub classes: usize,
    pub d: usize,
    pub features: usize,
    /// Calibrated cascade operating threshold, when the artifact has
    /// been through `loghd calibrate` (see `loghd::cascade`). `None`
    /// means never calibrated — the registry refuses to serve the
    /// artifact behind `--cascade` until it is.
    pub cascade_threshold: Option<f64>,
}

impl ModelCard {
    /// Read the identity of the artifact at `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let native = dir.join("model.json");
        if native.exists() {
            let text = std::fs::read_to_string(&native)
                .with_context(|| format!("reading {}", native.display()))?;
            let v = json::parse(&text)
                .map_err(|e| anyhow::anyhow!("{}: {e}", native.display()))?;
            let get = |key: &str| -> Result<usize> {
                v.get(key)
                    .and_then(json::Value::as_usize)
                    .with_context(|| format!("model.json missing {key}"))
            };
            return Ok(Self {
                dir: dir.to_path_buf(),
                kind: v
                    .get("kind")
                    .and_then(json::Value::as_str)
                    .unwrap_or("native-loghd")
                    .to_string(),
                classes: get("classes")?,
                d: get("d")?,
                features: get("features")?,
                cascade_threshold: v.get("cascade_threshold").and_then(json::Value::as_f64),
            });
        }
        if dir.join("manifest.json").exists() {
            return Ok(Manifest::load(dir)?.card());
        }
        bail!("{}: no model.json or manifest.json — not an artifact dir", dir.display())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lht_roundtrip() {
        let dir = std::env::temp_dir().join("loghd_lht_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.lht");
        write_lht_f32(&path, &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let t = read_lht(&path).unwrap();
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = t.to_matrix().unwrap();
        assert_eq!(m.rows(), 2);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn lht_rejects_garbage() {
        let dir = std::env::temp_dir().join("loghd_lht_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.lht");
        std::fs::write(&path, b"NOPE\x00\x01\x00\x00\x00\x00").unwrap();
        assert!(read_lht(&path).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn manifest_parses_minimal() {
        let dir = std::env::temp_dir().join("loghd_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
 "format": 1,
 "config": {"name": "t", "dataset": "page", "D": 64, "k": 2, "n": 3,
            "C": 5, "F": 10, "batch": 4, "extra_bundles": 0},
 "clean_accuracy": {"conventional": 0.9, "loghd": 0.8},
 "entries": [{"name": "encode", "hlo": "encode.hlo.txt",
   "inputs": [["x", [4, 10], "f32"]], "outputs": [["enc", [4, 64], "f32"]]}],
 "tensors": {"w": "w.lht"}
}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.d, 64);
        assert_eq!(m.batch, 4);
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entry("encode").unwrap().inputs[0].1, vec![4, 10]);
        assert!(m.entry("nope").is_none());
        assert!((m.clean_acc_loghd - 0.8).abs() < 1e-12);
        let card = ModelCard::load(&dir).unwrap();
        assert_eq!(card.kind, "aot-bundle");
        assert_eq!(card.features, 10);
        assert_eq!(card.classes, 5);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn model_card_reads_native_manifest() {
        let dir = std::env::temp_dir().join("loghd_card_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
 "format": 1, "kind": "native-conventional",
 "classes": 12, "d": 2000, "features": 261
}"#;
        std::fs::write(dir.join("model.json"), manifest).unwrap();
        let card = ModelCard::load(&dir).unwrap();
        assert_eq!(card.kind, "native-conventional");
        assert_eq!(card.features, 261);
        assert_eq!(card.d, 2000);
        assert_eq!(card.cascade_threshold, None, "uncalibrated artifact must read None");
        let with_threshold = r#"{
 "format": 1, "kind": "native-loghd",
 "classes": 12, "d": 2000, "features": 261, "cascade_threshold": 0.125
}"#;
        std::fs::write(dir.join("model.json"), with_threshold).unwrap();
        assert_eq!(ModelCard::load(&dir).unwrap().cascade_threshold, Some(0.125));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(ModelCard::load(&dir).is_err());
    }
}

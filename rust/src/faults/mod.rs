//! Fault injection (paper §IV-A plus analog extensions): perturbations
//! of the *stored model state* prior to evaluation. Test inputs are
//! never corrupted.
//!
//! Digital fault model: with probability `p`, each stored VALUE suffers
//! one flip of a uniformly-chosen bit of its representation
//! (`flip_values_*`). This is the standard memory-cell upset model and
//! the only reading consistent with the paper's figures: its x-axis
//! reaches p = 0.9 with non-trivial accuracy, which is impossible under
//! independent per-bit flips (at per-bit p = 0.2, 1-0.8^8 = 83% of all
//! 8-bit words are already corrupted — every method collapses). The
//! per-bit i.i.d. variant is also provided (`flip_positions`/
//! `flip_packed`) for ablations.
//!
//! Analog fault models ([`FaultModel`]) extend the digital one with the
//! dominant in-memory-compute fault surfaces (Karunaratne et al.,
//! "In-memory hyperdimensional computing"):
//!
//! - [`FaultModel::GaussianDrift`] — conductance drift: every stored
//!   value gains `sigma · A · z`, `z ~ N(0,1)`, where `A` is the
//!   plane's full-scale amplitude (max |value| for f32 planes, the
//!   quantizer rail for packed planes),
//! - [`FaultModel::StuckAt`] — a Bernoulli(`frac`) subset of cells is
//!   pinned to a conductance rail (`low` = −A, `high` = +A, `mixed` =
//!   fair coin per victim),
//! - [`FaultModel::LineFailure`] — correlated word-line failures: each
//!   row starts failing with probability `rate` and takes the next
//!   `span − 1` rows down with it; failed rows read as the low rail.
//!
//! Sampling ([`sample_plane_fault`]) is separated from application
//! (`apply_analog_f32` here, `quant::apply_analog_packed` for packed
//! planes) so every storage domain consumes the *same* rng stream for
//! the same fault model — the discipline that keeps campaign artifacts
//! bit-identical across thread counts. `FaultModel::BitFlip` draws
//! exactly the stream of [`value_flip_mask`], so the digital golden is
//! byte-identical through the analog entry point.
//!
//! For SparseHD the faults target only non-pruned coordinates (the
//! pruned ones are not stored); for LogHD they target both the bundles
//! and the stored activation profiles — exactly the paper's protocol.
//!
//! Implementation: geometric skip sampling over the value/bit stream —
//! O(flips) instead of O(total), exact for i.i.d. Bernoulli at any p.

use crate::quant::packed::PackedTensor;
use crate::util::rng::SplitMix64;

/// Sample the indices of flipped bits among `total_bits` independent
/// Bernoulli(p) trials, via geometric gaps.
pub fn flip_positions(total_bits: usize, p: f64, rng: &mut SplitMix64) -> Vec<usize> {
    assert!((0.0..=1.0).contains(&p), "flip probability {p} out of range");
    if p <= 0.0 || total_bits == 0 {
        return Vec::new();
    }
    if p >= 1.0 {
        return (0..total_bits).collect();
    }
    let ln_q = (1.0 - p).ln(); // < 0
    let mut out = Vec::new();
    let mut pos = 0usize;
    loop {
        // gap ~ Geometric(p): number of non-flips before the next flip
        let u = rng.uniform().max(f64::MIN_POSITIVE);
        let gap = (u.ln() / ln_q).floor() as usize;
        pos = match pos.checked_add(gap) {
            Some(v) => v,
            None => break,
        };
        if pos >= total_bits {
            break;
        }
        out.push(pos);
        pos += 1;
    }
    out
}

/// Storage that exposes its value/bit layout to the shared
/// draw-then-apply appliers. The one abstraction both storage domains
/// (packed level codes, raw f32 words) implement, so the digital and
/// analog paths share a single sampling entry point instead of the
/// former per-domain wrapper pairs.
pub trait FaultTarget {
    /// Number of stored values.
    fn value_count(&self) -> usize;
    /// Bits per stored value (32 for f32 storage).
    fn bits_per_value(&self) -> u32;
    /// Flip one bit of the flat `value_count() * bits_per_value()`
    /// storage-bit stream.
    fn flip_storage_bit(&mut self, pos: usize);
}

impl FaultTarget for PackedTensor {
    fn value_count(&self) -> usize {
        self.count()
    }

    fn bits_per_value(&self) -> u32 {
        self.bits()
    }

    fn flip_storage_bit(&mut self, pos: usize) {
        self.flip_bit(pos);
    }
}

impl FaultTarget for [f32] {
    fn value_count(&self) -> usize {
        self.len()
    }

    fn bits_per_value(&self) -> u32 {
        32
    }

    fn flip_storage_bit(&mut self, pos: usize) {
        let idx = pos / 32;
        let bit = pos % 32;
        self[idx] = f32::from_bits(self[idx].to_bits() ^ (1u32 << bit));
    }
}

/// Per-bit i.i.d. fault model on any [`FaultTarget`]: flip each storage
/// bit independently with probability `p`. Returns the number of flips.
pub fn flip_bits<T: FaultTarget + ?Sized>(t: &mut T, p: f64, rng: &mut SplitMix64) -> usize {
    let total = t.value_count() * t.bits_per_value() as usize;
    let positions = flip_positions(total, p, rng);
    for &pos in &positions {
        t.flip_storage_bit(pos);
    }
    positions.len()
}

/// Per-VALUE fault model on any [`FaultTarget`]: with probability `p`,
/// flip one uniformly-chosen bit of each stored value. Returns flips.
pub fn flip_values<T: FaultTarget + ?Sized>(t: &mut T, p: f64, rng: &mut SplitMix64) -> usize {
    let mask = value_flip_mask(t.value_count(), t.bits_per_value(), p, rng);
    apply_value_mask(t, &mask);
    mask.len()
}

/// Apply a sampled per-value flip mask: flip `bit` of value `v` for
/// every `(v, bit)` pair. The single mask-application rule every fault
/// site shares — the model core's plane driver
/// (`model::inject_faults` → `apply_flips`) and the differential tests
/// all route through it, so the bit addressing cannot drift between
/// storage domains.
pub fn apply_value_mask<T: FaultTarget + ?Sized>(t: &mut T, mask: &[(usize, u32)]) {
    let bits = t.bits_per_value() as usize;
    for &(v, bit) in mask {
        t.flip_storage_bit(v * bits + bit as usize);
    }
}

/// Flip bits of a packed tensor in place with probability `p` per bit.
pub fn flip_packed(t: &mut PackedTensor, p: f64, rng: &mut SplitMix64) -> usize {
    flip_bits(t, p, rng)
}

/// Flip bits in raw f32 storage under the per-bit i.i.d. model.
pub fn flip_f32(data: &mut [f32], p: f64, rng: &mut SplitMix64) -> usize {
    flip_bits(data, p, rng)
}

/// Sample the per-VALUE fault mask: each entry is a `(victim index,
/// bit-within-value)` pair, victims strictly increasing. Drawing the
/// mask is separated from applying it so differential tests can apply
/// the *same* seeded mask to a packed tensor and to its dequantized
/// dense twin (`flip_values_packed`/`flip_values_f32` are thin appliers
/// over this sampler and consume the stream identically).
pub fn value_flip_mask(
    count: usize,
    bits: u32,
    p: f64,
    rng: &mut SplitMix64,
) -> Vec<(usize, u32)> {
    let victims = flip_positions(count, p, rng);
    victims.into_iter().map(|v| (v, rng.below(bits as u64) as u32)).collect()
}

/// Apply a sampled per-value flip mask to a packed tensor.
pub fn apply_value_mask_packed(t: &mut PackedTensor, mask: &[(usize, u32)]) {
    apply_value_mask(t, mask);
}

/// Apply a sampled per-value flip mask to raw f32 storage (the IEEE-754
/// word of value `v` has `bit` xored).
pub fn apply_value_mask_f32(data: &mut [f32], mask: &[(usize, u32)]) {
    apply_value_mask(data, mask);
}

/// Per-VALUE fault model (the evaluation protocol) on a packed tensor.
pub fn flip_values_packed(t: &mut PackedTensor, p: f64, rng: &mut SplitMix64) -> usize {
    flip_values(t, p, rng)
}

/// Per-VALUE fault model on raw f32 storage.
pub fn flip_values_f32(data: &mut [f32], p: f64, rng: &mut SplitMix64) -> usize {
    flip_values(data, p, rng)
}

/// Rail a stuck cell is pinned to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StuckPolarity {
    /// Every victim reads the low rail (−A / minimum level code).
    Low,
    /// Every victim reads the high rail (+A / maximum level code).
    High,
    /// Fair coin per victim (one extra draw each, in victim order).
    Mixed,
}

impl StuckPolarity {
    pub fn label(self) -> &'static str {
        match self {
            StuckPolarity::Low => "low",
            StuckPolarity::High => "high",
            StuckPolarity::Mixed => "mixed",
        }
    }
}

/// A memory fault model, parameterized at one severity point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultModel {
    /// Digital per-value upset: with probability `p` a stored value has
    /// one uniformly-chosen bit of its representation flipped. Draws
    /// exactly the [`value_flip_mask`] stream.
    BitFlip { p: f64 },
    /// Gaussian conductance drift: every stored value gains
    /// `sigma · A · z` with `z ~ N(0,1)` and `A` the plane amplitude.
    GaussianDrift { sigma: f64 },
    /// A Bernoulli(`frac`) subset of cells pinned to a rail.
    StuckAt { frac: f64, polarity: StuckPolarity },
    /// Correlated row failures: each row starts failing with
    /// probability `rate`; a failure takes the following `span − 1`
    /// rows down too. Failed rows read as the low rail.
    LineFailure { rate: f64, span: usize },
}

impl FaultModel {
    pub fn kind(&self) -> FaultModelKind {
        match self {
            FaultModel::BitFlip { .. } => FaultModelKind::BitFlip,
            FaultModel::GaussianDrift { .. } => FaultModelKind::GaussianDrift,
            FaultModel::StuckAt { .. } => FaultModelKind::StuckAt,
            FaultModel::LineFailure { .. } => FaultModelKind::LineFailure,
        }
    }
}

/// The four fault-model families, parameter-free (the campaign sweeps
/// each over a normalized severity grid).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultModelKind {
    BitFlip,
    GaussianDrift,
    StuckAt,
    LineFailure,
}

impl FaultModelKind {
    pub const ALL: [Self; 4] =
        [Self::BitFlip, Self::GaussianDrift, Self::StuckAt, Self::LineFailure];

    pub fn label(self) -> &'static str {
        match self {
            FaultModelKind::BitFlip => "bitflip",
            FaultModelKind::GaussianDrift => "drift",
            FaultModelKind::StuckAt => "stuckat",
            FaultModelKind::LineFailure => "line",
        }
    }

    /// Parse a CLI spelling (`--fault-model`), accepting the common
    /// aliases. Returns `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "bitflip" | "flip" | "digital" => Some(FaultModelKind::BitFlip),
            "drift" | "gaussian" => Some(FaultModelKind::GaussianDrift),
            "stuckat" | "stuck" | "sa" => Some(FaultModelKind::StuckAt),
            "line" | "lines" | "wordline" => Some(FaultModelKind::LineFailure),
            _ => None,
        }
    }

    /// Per-kind salt folded into the Monte-Carlo cell stream seed.
    /// `BitFlip` salts with 0 so the analog entry point reproduces the
    /// digital campaign stream byte-for-byte.
    pub fn stream_salt(self) -> u64 {
        match self {
            FaultModelKind::BitFlip => 0,
            FaultModelKind::GaussianDrift => 0xD21F_7A11,
            FaultModelKind::StuckAt => 0x57C4_A7A7,
            FaultModelKind::LineFailure => 0x11FE_FA11,
        }
    }

    /// Instantiate this kind at normalized severity `t ∈ [0, 1]`-ish
    /// (the shared campaign grid). The grids are normalized so each
    /// model's curve is comparable at the same `t`:
    ///
    /// - bitflip: `p = t` (the paper's axis, unchanged),
    /// - drift: `sigma = drift_sigma_max · t` (full-scale units),
    /// - stuckat: `frac = t`, mixed polarity,
    /// - line: `rate = 1 − (1 − t)^(1/span)`, so the *expected
    ///   corrupted-row fraction* is ≈ `t` after span expansion.
    ///
    /// `t = 0` is a no-op under every kind (zero rng draws), keeping
    /// the clean grid point exactly clean.
    pub fn at_severity(self, t: f64, span: usize, drift_sigma_max: f64) -> FaultModel {
        match self {
            FaultModelKind::BitFlip => FaultModel::BitFlip { p: t },
            FaultModelKind::GaussianDrift => {
                FaultModel::GaussianDrift { sigma: drift_sigma_max * t }
            }
            FaultModelKind::StuckAt => {
                FaultModel::StuckAt { frac: t, polarity: StuckPolarity::Mixed }
            }
            FaultModelKind::LineFailure => {
                let span = span.max(1);
                let rate = 1.0 - (1.0 - t).powf(1.0 / span as f64);
                FaultModel::LineFailure { rate, span }
            }
        }
    }
}

/// One sampled fault realization for one plane — storage-domain
/// agnostic, so the same realization can be applied to an f32 plane
/// ([`apply_analog_f32`]) or a packed one (`quant::apply_analog_packed`).
#[derive(Debug, Clone, PartialEq)]
pub enum PlaneFault {
    /// Digital per-value bit flips (`(victim, bit-within-value)`).
    Flips(Vec<(usize, u32)>),
    /// Per-value z-scores; value `i` gains `sigma · A · z[i]`.
    Drift { sigma: f32, z: Vec<f32> },
    /// `(victim, stuck-high)` pairs, victims strictly increasing.
    Stuck(Vec<(usize, bool)>),
    /// Failed row indices, strictly increasing.
    Lines(Vec<usize>),
}

impl PlaneFault {
    pub fn is_empty(&self) -> bool {
        match self {
            PlaneFault::Flips(m) => m.is_empty(),
            PlaneFault::Drift { z, .. } => z.is_empty(),
            PlaneFault::Stuck(c) => c.is_empty(),
            PlaneFault::Lines(r) => r.is_empty(),
        }
    }

    /// Number of stored values this realization touches (`cols` is the
    /// plane's row width, needed for the row-granular line model).
    pub fn touched(&self, cols: usize) -> usize {
        match self {
            PlaneFault::Flips(m) => m.len(),
            PlaneFault::Drift { z, .. } => z.len(),
            PlaneFault::Stuck(c) => c.len(),
            PlaneFault::Lines(r) => r.len() * cols,
        }
    }
}

/// Sample one plane's fault realization from `model`. Draw discipline
/// (per plane, in surface order — the contract the campaign streams
/// rely on):
///
/// - `BitFlip{p}`: exactly the [`value_flip_mask`] stream (zero draws
///   at `p = 0`),
/// - `GaussianDrift{sigma}`: `rows·cols` normals (2 uniforms each);
///   zero draws at `sigma ≤ 0`,
/// - `StuckAt{frac, polarity}`: a [`flip_positions`] victim draw, plus
///   one coin per victim iff polarity is `mixed`,
/// - `LineFailure{rate, span}`: a [`flip_positions`] draw over rows;
///   span expansion consumes no draws.
pub fn sample_plane_fault(
    model: &FaultModel,
    rows: usize,
    cols: usize,
    bits: u32,
    rng: &mut SplitMix64,
) -> PlaneFault {
    let values = rows * cols;
    match *model {
        FaultModel::BitFlip { p } => PlaneFault::Flips(value_flip_mask(values, bits, p, rng)),
        FaultModel::GaussianDrift { sigma } => {
            assert!(sigma.is_finite() && sigma >= 0.0, "drift sigma {sigma} out of range");
            if sigma <= 0.0 || values == 0 {
                PlaneFault::Drift { sigma: 0.0, z: Vec::new() }
            } else {
                PlaneFault::Drift { sigma: sigma as f32, z: rng.normals_f32(values) }
            }
        }
        FaultModel::StuckAt { frac, polarity } => {
            let victims = flip_positions(values, frac, rng);
            let cells = victims
                .into_iter()
                .map(|v| {
                    let high = match polarity {
                        StuckPolarity::Low => false,
                        StuckPolarity::High => true,
                        StuckPolarity::Mixed => rng.below(2) == 1,
                    };
                    (v, high)
                })
                .collect();
            PlaneFault::Stuck(cells)
        }
        FaultModel::LineFailure { rate, span } => {
            let span = span.max(1);
            let starts = flip_positions(rows, rate, rng);
            let mut failed: Vec<usize> = Vec::new();
            for s in starts {
                let begin = failed.last().map_or(s, |&last| s.max(last + 1));
                for r in begin..(s + span).min(rows) {
                    failed.push(r);
                }
            }
            PlaneFault::Lines(failed)
        }
    }
}

/// Full-scale amplitude of an f32 plane — the analog rail the drift /
/// stuck-at / line models reference (the conductance range maps to
/// ±max |value|; floor keeps all-zero planes well-defined).
pub fn plane_amplitude(data: &[f32]) -> f32 {
    data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12)
}

/// Apply a sampled plane fault to f32 storage. `cols` is the plane's
/// row width (row `r` occupies `data[r*cols .. (r+1)*cols]`).
pub fn apply_analog_f32(data: &mut [f32], cols: usize, fault: &PlaneFault) {
    match fault {
        PlaneFault::Flips(mask) => apply_value_mask(data, mask),
        PlaneFault::Drift { sigma, z } => {
            if z.is_empty() {
                return;
            }
            assert_eq!(z.len(), data.len(), "drift field does not match plane size");
            let amp = plane_amplitude(data);
            for (v, zi) in data.iter_mut().zip(z) {
                *v += sigma * amp * zi;
            }
        }
        PlaneFault::Stuck(cells) => {
            let amp = plane_amplitude(data);
            for &(v, high) in cells {
                data[v] = if high { amp } else { -amp };
            }
        }
        PlaneFault::Lines(rows) => {
            let amp = plane_amplitude(data);
            for &r in rows {
                for v in &mut data[r * cols..(r + 1) * cols] {
                    *v = -amp;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p_zero_flips_nothing() {
        let mut rng = SplitMix64::new(1);
        assert!(flip_positions(10_000, 0.0, &mut rng).is_empty());
    }

    #[test]
    fn p_one_flips_everything() {
        let mut rng = SplitMix64::new(1);
        assert_eq!(flip_positions(100, 1.0, &mut rng).len(), 100);
    }

    #[test]
    fn empirical_rate_matches_p() {
        let mut rng = SplitMix64::new(42);
        for &p in &[0.01, 0.1, 0.5, 0.9] {
            let total = 200_000;
            let flips = flip_positions(total, p, &mut rng).len() as f64;
            let rate = flips / total as f64;
            let sigma = (p * (1.0 - p) / total as f64).sqrt();
            assert!(
                (rate - p).abs() < 6.0 * sigma + 1e-4,
                "p={p}: rate {rate} off by more than 6 sigma"
            );
        }
    }

    #[test]
    fn positions_strictly_increasing_and_in_range() {
        let mut rng = SplitMix64::new(9);
        let pos = flip_positions(5000, 0.3, &mut rng);
        for w in pos.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(pos.iter().all(|&i| i < 5000));
    }

    #[test]
    fn packed_flip_count_matches() {
        let mut rng = SplitMix64::new(5);
        let mut t = PackedTensor::new(8, 1000);
        let flips = flip_packed(&mut t, 0.05, &mut rng);
        // count set bits (t started all-zero, each flip sets one bit —
        // collisions impossible since positions are unique)
        let ones: u32 = t.words().iter().map(|w| w.count_ones()).sum();
        assert_eq!(ones as usize, flips);
    }

    #[test]
    fn f32_flip_changes_values() {
        let mut rng = SplitMix64::new(6);
        let mut data = vec![1.0f32; 64];
        let flips = flip_f32(&mut data, 0.02, &mut rng);
        let changed = data.iter().filter(|v| **v != 1.0).count();
        assert!(flips > 0);
        assert!(changed > 0 && changed <= flips);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = flip_positions(1000, 0.2, &mut SplitMix64::new(7));
        let b = flip_positions(1000, 0.2, &mut SplitMix64::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn value_flip_mask_matches_packed_applier() {
        // Applying the sampled mask by hand must reproduce
        // flip_values_packed from the same seed: same stream, same flips.
        let mut t_direct = PackedTensor::new(8, 500);
        let mut t_manual = t_direct.clone();
        let flips = flip_values_packed(&mut t_direct, 0.3, &mut SplitMix64::new(11));
        let mask = value_flip_mask(500, 8, 0.3, &mut SplitMix64::new(11));
        assert_eq!(mask.len(), flips);
        for &(v, bit) in &mask {
            assert!(bit < 8);
            t_manual.flip_bit(v * 8 + bit as usize);
        }
        assert_eq!(t_manual, t_direct);
        // victims strictly increasing (duplicate-free by construction)
        for w in mask.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn value_flip_mask_matches_f32_applier() {
        let mut direct = vec![1.0f32; 300];
        let flips = flip_values_f32(&mut direct, 0.25, &mut SplitMix64::new(13));
        let mask = value_flip_mask(300, 32, 0.25, &mut SplitMix64::new(13));
        assert_eq!(mask.len(), flips);
        let mut manual = vec![1.0f32; 300];
        for &(v, bit) in &mask {
            manual[v] = f32::from_bits(manual[v].to_bits() ^ (1u32 << bit));
        }
        assert_eq!(manual, direct);
    }

    #[test]
    fn bitflip_model_draws_the_value_mask_stream() {
        // The analog entry point must reproduce the digital sampler's
        // stream exactly — the invariant the committed digital golden
        // rides on.
        let mut a = SplitMix64::new(77);
        let mut b = SplitMix64::new(77);
        let fault = sample_plane_fault(&FaultModel::BitFlip { p: 0.3 }, 20, 25, 8, &mut a);
        let mask = value_flip_mask(500, 8, 0.3, &mut b);
        assert_eq!(fault, PlaneFault::Flips(mask));
        assert_eq!(a.next_u64(), b.next_u64(), "stream positions diverged");
    }

    #[test]
    fn zero_severity_consumes_no_draws_for_every_kind() {
        for kind in FaultModelKind::ALL {
            let model = kind.at_severity(0.0, 2, 2.0);
            let mut rng = SplitMix64::new(3);
            let mut probe = rng.clone();
            let fault = sample_plane_fault(&model, 8, 16, 8, &mut rng);
            assert!(fault.is_empty(), "{}: non-empty fault at t=0", kind.label());
            assert_eq!(
                rng.next_u64(),
                probe.next_u64(),
                "{}: rng consumed at t=0",
                kind.label()
            );
        }
    }

    #[test]
    fn drift_perturbs_at_plane_scale() {
        let mut rng = SplitMix64::new(21);
        let fault = sample_plane_fault(
            &FaultModel::GaussianDrift { sigma: 0.1 },
            10,
            10,
            32,
            &mut rng,
        );
        let mut data = vec![2.0f32; 100];
        apply_analog_f32(&mut data, 10, &fault);
        assert!(data.iter().any(|&v| v != 2.0));
        // amplitude was 2.0, so perturbations are ~N(0, 0.2) around 2.0
        let mean = data.iter().sum::<f32>() / 100.0;
        assert!((mean - 2.0).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn stuck_cells_sit_on_the_rails() {
        let mut rng = SplitMix64::new(33);
        let fault = sample_plane_fault(
            &FaultModel::StuckAt { frac: 0.5, polarity: StuckPolarity::High },
            1,
            200,
            32,
            &mut rng,
        );
        let cells = match &fault {
            PlaneFault::Stuck(c) => c.clone(),
            other => panic!("expected Stuck, got {other:?}"),
        };
        assert!(cells.iter().all(|&(_, high)| high));
        let mut data = vec![-0.5f32; 200];
        apply_analog_f32(&mut data, 200, &fault);
        for &(v, _) in &cells {
            assert_eq!(data[v], 0.5, "victim {v} not pinned to +A");
        }
    }

    #[test]
    fn line_failures_cover_contiguous_spans() {
        let mut rng = SplitMix64::new(55);
        let fault = sample_plane_fault(
            &FaultModel::LineFailure { rate: 0.2, span: 3 },
            40,
            8,
            32,
            &mut rng,
        );
        let rows = match &fault {
            PlaneFault::Lines(r) => r.clone(),
            other => panic!("expected Lines, got {other:?}"),
        };
        assert!(!rows.is_empty());
        for w in rows.windows(2) {
            assert!(w[0] < w[1], "rows not strictly increasing: {rows:?}");
        }
        assert!(rows.iter().all(|&r| r < 40));
        let mut data = vec![1.0f32; 40 * 8];
        apply_analog_f32(&mut data, 8, &fault);
        for r in 0..40 {
            let failed = rows.contains(&r);
            for c in 0..8 {
                let v = data[r * 8 + c];
                if failed {
                    assert_eq!(v, -1.0, "row {r} should read the low rail");
                } else {
                    assert_eq!(v, 1.0, "row {r} should be untouched");
                }
            }
        }
    }

    #[test]
    fn kind_parse_round_trips_labels() {
        for kind in FaultModelKind::ALL {
            assert_eq!(FaultModelKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(FaultModelKind::parse("no-such-model"), None);
    }

    #[test]
    fn line_severity_normalization_hits_expected_row_fraction() {
        // rate = 1 - (1-t)^(1/span) means P(row in some span) ≈ t.
        let model = FaultModelKind::LineFailure.at_severity(0.3, 2, 2.0);
        let FaultModel::LineFailure { rate, span } = model else {
            panic!("wrong kind");
        };
        assert_eq!(span, 2);
        let coverage = 1.0 - (1.0 - rate) * (1.0 - rate);
        assert!((coverage - 0.3).abs() < 1e-12, "coverage {coverage}");
    }
}

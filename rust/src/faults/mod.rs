//! Fault injection (paper §IV-A): random bit flips with probability `p`
//! applied to the *stored model state* prior to evaluation. Test inputs
//! are never corrupted.
//!
//! Fault model: with probability `p`, each stored VALUE suffers one flip
//! of a uniformly-chosen bit of its representation (`flip_values_*`).
//! This is the standard memory-cell upset model and the only reading
//! consistent with the paper's figures: its x-axis reaches p = 0.9 with
//! non-trivial accuracy, which is impossible under independent per-bit
//! flips (at per-bit p = 0.2, 1-0.8^8 = 83% of all 8-bit words are already
//! corrupted — every method collapses). The per-bit i.i.d. variant is also
//! provided (`flip_positions`/`flip_packed`) for ablations.
//!
//! For SparseHD the flips target only non-pruned coordinates (the pruned
//! ones are not stored); for LogHD they target both the bundles and the
//! stored activation profiles — exactly the paper's protocol.
//!
//! Implementation: geometric skip sampling over the value/bit stream —
//! O(flips) instead of O(total), exact for i.i.d. Bernoulli at any p.

use crate::quant::packed::PackedTensor;
use crate::util::rng::SplitMix64;

/// Sample the indices of flipped bits among `total_bits` independent
/// Bernoulli(p) trials, via geometric gaps.
pub fn flip_positions(total_bits: usize, p: f64, rng: &mut SplitMix64) -> Vec<usize> {
    assert!((0.0..=1.0).contains(&p), "flip probability {p} out of range");
    if p <= 0.0 || total_bits == 0 {
        return Vec::new();
    }
    if p >= 1.0 {
        return (0..total_bits).collect();
    }
    let ln_q = (1.0 - p).ln(); // < 0
    let mut out = Vec::new();
    let mut pos = 0usize;
    loop {
        // gap ~ Geometric(p): number of non-flips before the next flip
        let u = rng.uniform().max(f64::MIN_POSITIVE);
        let gap = (u.ln() / ln_q).floor() as usize;
        pos = match pos.checked_add(gap) {
            Some(v) => v,
            None => break,
        };
        if pos >= total_bits {
            break;
        }
        out.push(pos);
        pos += 1;
    }
    out
}

/// Flip bits of a packed tensor in place with probability `p` per bit.
/// Returns the number of flips.
pub fn flip_packed(t: &mut PackedTensor, p: f64, rng: &mut SplitMix64) -> usize {
    let positions = flip_positions(t.total_bits(), p, rng);
    for &pos in &positions {
        t.flip_bit(pos);
    }
    positions.len()
}

/// Flip bits in raw f32 storage under the per-bit i.i.d. model.
pub fn flip_f32(data: &mut [f32], p: f64, rng: &mut SplitMix64) -> usize {
    let total = data.len() * 32;
    let positions = flip_positions(total, p, rng);
    for &pos in &positions {
        let idx = pos / 32;
        let bit = pos % 32;
        let bits = data[idx].to_bits() ^ (1u32 << bit);
        data[idx] = f32::from_bits(bits);
    }
    positions.len()
}

/// Sample the per-VALUE fault mask: each entry is a `(victim index,
/// bit-within-value)` pair, victims strictly increasing. Drawing the
/// mask is separated from applying it so differential tests can apply
/// the *same* seeded mask to a packed tensor and to its dequantized
/// dense twin (`flip_values_packed`/`flip_values_f32` are thin appliers
/// over this sampler and consume the stream identically).
pub fn value_flip_mask(
    count: usize,
    bits: u32,
    p: f64,
    rng: &mut SplitMix64,
) -> Vec<(usize, u32)> {
    let victims = flip_positions(count, p, rng);
    victims.into_iter().map(|v| (v, rng.below(bits as u64) as u32)).collect()
}

/// Apply a sampled per-value flip mask to a packed tensor: flip `bit`
/// of field `v` for every `(v, bit)` pair. The single mask-application
/// rule every packed fault site shares — [`flip_values_packed`], the
/// model core's plane driver (`model::inject_value_faults` →
/// `apply_flips`), and the differential tests all route through it, so
/// the bit addressing cannot drift between them.
pub fn apply_value_mask_packed(t: &mut PackedTensor, mask: &[(usize, u32)]) {
    let bits = t.bits() as usize;
    for &(v, bit) in mask {
        t.flip_bit(v * bits + bit as usize);
    }
}

/// Apply a sampled per-value flip mask to raw f32 storage (the IEEE-754
/// word of value `v` has `bit` xored). Twin of
/// [`apply_value_mask_packed`] for the f32 planes.
pub fn apply_value_mask_f32(data: &mut [f32], mask: &[(usize, u32)]) {
    for &(v, bit) in mask {
        data[v] = f32::from_bits(data[v].to_bits() ^ (1u32 << bit));
    }
}

/// Per-VALUE fault model (the evaluation protocol): with probability `p`,
/// flip one uniformly-chosen bit of each packed field. Returns flips.
pub fn flip_values_packed(t: &mut PackedTensor, p: f64, rng: &mut SplitMix64) -> usize {
    let mask = value_flip_mask(t.count(), t.bits(), p, rng);
    apply_value_mask_packed(t, &mask);
    mask.len()
}

/// Per-VALUE fault model on raw f32 storage.
pub fn flip_values_f32(data: &mut [f32], p: f64, rng: &mut SplitMix64) -> usize {
    let mask = value_flip_mask(data.len(), 32, p, rng);
    apply_value_mask_f32(data, &mask);
    mask.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p_zero_flips_nothing() {
        let mut rng = SplitMix64::new(1);
        assert!(flip_positions(10_000, 0.0, &mut rng).is_empty());
    }

    #[test]
    fn p_one_flips_everything() {
        let mut rng = SplitMix64::new(1);
        assert_eq!(flip_positions(100, 1.0, &mut rng).len(), 100);
    }

    #[test]
    fn empirical_rate_matches_p() {
        let mut rng = SplitMix64::new(42);
        for &p in &[0.01, 0.1, 0.5, 0.9] {
            let total = 200_000;
            let flips = flip_positions(total, p, &mut rng).len() as f64;
            let rate = flips / total as f64;
            let sigma = (p * (1.0 - p) / total as f64).sqrt();
            assert!(
                (rate - p).abs() < 6.0 * sigma + 1e-4,
                "p={p}: rate {rate} off by more than 6 sigma"
            );
        }
    }

    #[test]
    fn positions_strictly_increasing_and_in_range() {
        let mut rng = SplitMix64::new(9);
        let pos = flip_positions(5000, 0.3, &mut rng);
        for w in pos.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(pos.iter().all(|&i| i < 5000));
    }

    #[test]
    fn packed_flip_count_matches() {
        let mut rng = SplitMix64::new(5);
        let mut t = PackedTensor::new(8, 1000);
        let flips = flip_packed(&mut t, 0.05, &mut rng);
        // count set bits (t started all-zero, each flip sets one bit —
        // collisions impossible since positions are unique)
        let ones: u32 = t.words().iter().map(|w| w.count_ones()).sum();
        assert_eq!(ones as usize, flips);
    }

    #[test]
    fn f32_flip_changes_values() {
        let mut rng = SplitMix64::new(6);
        let mut data = vec![1.0f32; 64];
        let flips = flip_f32(&mut data, 0.02, &mut rng);
        let changed = data.iter().filter(|v| **v != 1.0).count();
        assert!(flips > 0);
        assert!(changed > 0 && changed <= flips);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = flip_positions(1000, 0.2, &mut SplitMix64::new(7));
        let b = flip_positions(1000, 0.2, &mut SplitMix64::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn value_flip_mask_matches_packed_applier() {
        // Applying the sampled mask by hand must reproduce
        // flip_values_packed from the same seed: same stream, same flips.
        let mut t_direct = PackedTensor::new(8, 500);
        let mut t_manual = t_direct.clone();
        let flips = flip_values_packed(&mut t_direct, 0.3, &mut SplitMix64::new(11));
        let mask = value_flip_mask(500, 8, 0.3, &mut SplitMix64::new(11));
        assert_eq!(mask.len(), flips);
        for &(v, bit) in &mask {
            assert!(bit < 8);
            t_manual.flip_bit(v * 8 + bit as usize);
        }
        assert_eq!(t_manual, t_direct);
        // victims strictly increasing (duplicate-free by construction)
        for w in mask.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn value_flip_mask_matches_f32_applier() {
        let mut direct = vec![1.0f32; 300];
        let flips = flip_values_f32(&mut direct, 0.25, &mut SplitMix64::new(13));
        let mask = value_flip_mask(300, 32, 0.25, &mut SplitMix64::new(13));
        assert_eq!(mask.len(), flips);
        let mut manual = vec![1.0f32; 300];
        for &(v, bit) in &mask {
            manual[v] = f32::from_bits(manual[v].to_bits() ^ (1u32 << bit));
        }
        assert_eq!(manual, direct);
    }
}

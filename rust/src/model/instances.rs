//! Concrete [`HdClassifier`] instances: each model family materialized
//! at a serving precision, with its stored state held in the exact
//! bit-plane representation the fault injector corrupts.
//!
//! An *instance* is the precision-tagged snapshot of a trained family
//! model: f32 planes hold the raw tensors, sub-f32 planes hold the
//! packed quantizer output ([`Quantized`]), and `predict` scores the
//! *current* plane contents (dequantizing on the fly where no packed
//! kernel exists). The 1/8-bit LogHD widths are served by
//! [`QuantizedLogHdModel`] itself (which implements the trait and runs
//! fully in the packed domain); everything else lives here.
//!
//! **Plane-order contract** (see [`crate::model`] docs): surfaces
//! enumerate planes in the order the pre-trait corruption helpers drew
//! them — bundles first, then per-column profile deviations, then the
//! profile mean — so campaign artifacts stay byte-identical across the
//! trait migration.

use crate::baselines::{DecoHdModel, HybridModel, SparseHdModel};
use crate::faults::PlaneFault;
use crate::hd::similarity::activations;
use crate::loghd::codebook::Codebook;
use crate::loghd::model::LogHdModel;
use crate::loghd::qmodel::QuantizedLogHdModel;
use crate::quant::{self, Precision, Quantized};
use crate::tensor::{self, Matrix};

use super::{FaultPlane, FaultSurface, HdClassifier};

/// Gather a subset of columns (the stored coordinates of a masked
/// model) into a dense matrix, in mask order.
pub fn gather_cols(m: &Matrix, kept: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(m.rows(), kept.len());
    for r in 0..m.rows() {
        let src = m.row(r);
        for (dst, &j) in out.row_mut(r).iter_mut().zip(kept) {
            *dst = src[j];
        }
    }
    out
}

fn kept_indices(mask: &[bool]) -> Vec<usize> {
    mask.iter().enumerate().filter(|(_, keep)| **keep).map(|(i, _)| i).collect()
}

/// Per-row decode margin over a (B, C) squared-distance matrix: the gap
/// between the runner-up and the best (lowest) distance, under the same
/// lowest-index-wins tie discipline as [`tensor::argmin`] — a tied
/// runner-up yields margin 0, so a cascade gated on `margin >= t` with
/// `t > 0` always escalates ties. Single-class rows have no runner-up
/// and report `f32::INFINITY`. `margins` is cleared and refilled with
/// one value per row; its capacity is reused across calls (no
/// steady-state allocation once it has reached its high-water mark).
pub fn distance_margins_into(dists: &Matrix, margins: &mut Vec<f32>) {
    margins.clear();
    for i in 0..dists.rows() {
        let row = dists.row(i);
        let best = tensor::argmin(row);
        let mut runner = f32::INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if j != best && v < runner {
                runner = v;
            }
        }
        margins.push(runner - row[best]);
    }
}

/// One stored tensor at the instance's precision: raw f32 words, or the
/// packed quantizer output. Either way the plane IS the fault surface —
/// flips land on exactly these bits.
enum PlaneState {
    F32(Matrix),
    Q(Quantized),
}

impl PlaneState {
    fn build(m: &Matrix, precision: Precision) -> Self {
        match precision {
            Precision::F32 => PlaneState::F32(m.clone()),
            p => PlaneState::Q(quant::quantize(m, p)),
        }
    }

    fn plane(&self, label: &str) -> FaultPlane {
        match self {
            PlaneState::F32(m) => FaultPlane::with_shape(label, m.rows(), m.cols(), 32),
            PlaneState::Q(q) => FaultPlane::with_shape(label, q.rows, q.cols, q.packed.bits()),
        }
    }

    /// Apply a per-value flip mask through the shared `faults` appliers
    /// (the same code `flip_values_f32` / `flip_values_packed` run).
    fn apply(&mut self, mask: &[(usize, u32)]) {
        match self {
            PlaneState::F32(m) => crate::faults::apply_value_mask_f32(m.data_mut(), mask),
            PlaneState::Q(q) => crate::faults::apply_value_mask_packed(&mut q.packed, mask),
        }
    }

    /// Apply a sampled plane fault in the value domain: f32 planes
    /// through `faults::apply_analog_f32`, packed planes through their
    /// conductance-level mapping (`quant::apply_analog_packed`).
    fn apply_fault(&mut self, fault: &PlaneFault) {
        match self {
            PlaneState::F32(m) => {
                let cols = m.cols();
                crate::faults::apply_analog_f32(m.data_mut(), cols, fault);
            }
            PlaneState::Q(q) => quant::apply_analog_packed(&mut q.packed, q.cols, fault),
        }
    }

    /// Dense view of the current (possibly corrupted) plane contents.
    fn dense(&self) -> Matrix {
        match self {
            PlaneState::F32(m) => m.clone(),
            PlaneState::Q(q) => quant::dequantize(q),
        }
    }
}

/// The robust stored form of the (C, n) activation profiles: per-bundle
/// column deviations from the cross-class mean, plus that mean — each a
/// separately quantized plane, exactly as `eval::sweep::corrupt_profiles`
/// corrupted them (and as the packed twin's `StoredProfiles` stores them).
struct ProfilePlanes {
    classes: usize,
    n: usize,
    cols: Vec<PlaneState>,
    mean: PlaneState,
}

impl ProfilePlanes {
    fn build(profiles: &Matrix, precision: Precision) -> Self {
        let (classes, n) = (profiles.rows(), profiles.cols());
        let mean = tensor::col_means(profiles);
        let mut dev = profiles.clone();
        tensor::sub_row_inplace(&mut dev, &mean);
        let cols = (0..n)
            .map(|j| {
                let col: Vec<f32> = (0..classes).map(|r| dev.at(r, j)).collect();
                PlaneState::build(&Matrix::from_vec(classes, 1, col), precision)
            })
            .collect();
        let mean = PlaneState::build(&Matrix::from_vec(1, n, mean), precision);
        Self { classes, n, cols, mean }
    }

    /// Surface planes in stream order: column 0..n-1, then the mean.
    fn planes(&self) -> Vec<FaultPlane> {
        let mut out: Vec<FaultPlane> = (0..self.n)
            .map(|j| self.cols[j].plane(&format!("profiles[{j}]")))
            .collect();
        out.push(self.mean.plane("profile_mean"));
        out
    }

    fn apply(&mut self, idx: usize, mask: &[(usize, u32)]) {
        if idx < self.n {
            self.cols[idx].apply(mask);
        } else {
            self.mean.apply(mask);
        }
    }

    fn apply_fault(&mut self, idx: usize, fault: &PlaneFault) {
        if idx < self.n {
            self.cols[idx].apply_fault(fault);
        } else {
            self.mean.apply_fault(fault);
        }
    }

    /// Reassemble the (C, n) profile matrix from the current planes.
    fn assemble(&self) -> Matrix {
        let mean = self.mean.dense();
        let mut out = Matrix::zeros(self.classes, self.n);
        for (j, col) in self.cols.iter().enumerate() {
            let col = col.dense();
            for r in 0..self.classes {
                out.set(r, j, col.at(r, 0) + mean.at(0, j));
            }
        }
        out
    }
}

/// Per-row argmax with the pinned **lowest-index-wins** tie discipline
/// (inherited from [`tensor::argmax`]). The cascade's agreement
/// accounting depends on the b1 and exact decode paths resolving ties
/// identically, so this contract is property-tested below.
fn argmax_rows(scores: &Matrix) -> Vec<i32> {
    (0..scores.rows()).map(|i| tensor::argmax(scores.row(i)) as i32).collect()
}

// ---------------------------------------------------------------------
// Conventional
// ---------------------------------------------------------------------

/// The O(C·D) baseline at one precision: one prototype plane.
struct ConventionalInstance {
    classes: usize,
    d: usize,
    prototypes: PlaneState,
}

impl HdClassifier for ConventionalInstance {
    fn kind(&self) -> &'static str {
        "conventional"
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn d(&self) -> usize {
        self.d
    }
    fn decode_activations(&self, enc: &Matrix) -> Matrix {
        activations(enc, &self.prototypes.dense())
    }
    fn predict(&self, enc: &Matrix) -> Vec<i32> {
        argmax_rows(&self.decode_activations(enc))
    }
    fn fault_surface(&self) -> FaultSurface {
        FaultSurface::new(vec![self.prototypes.plane("prototypes")])
    }
    fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]) {
        debug_assert_eq!(plane, 0);
        self.prototypes.apply(mask);
    }
    fn apply_fault(&mut self, plane: usize, fault: &PlaneFault) {
        debug_assert_eq!(plane, 0);
        self.prototypes.apply_fault(fault);
    }
}

/// Build the conventional instance from a (C, D) prototype matrix.
pub fn conventional(prototypes: &Matrix, precision: Precision) -> Box<dyn HdClassifier> {
    Box::new(ConventionalInstance {
        classes: prototypes.rows(),
        d: prototypes.cols(),
        prototypes: PlaneState::build(prototypes, precision),
    })
}

// ---------------------------------------------------------------------
// SparseHD
// ---------------------------------------------------------------------

/// SparseHD at one precision: only the retained coordinates are stored
/// (one compact plane); pruned coordinates are identically zero and
/// outside the fault surface.
struct SparseInstance {
    classes: usize,
    d: usize,
    kept: Vec<usize>,
    compact: PlaneState,
}

impl SparseInstance {
    fn scatter(&self) -> Matrix {
        let compact = self.compact.dense();
        let mut out = Matrix::zeros(self.classes, self.d);
        for r in 0..self.classes {
            let dst = out.row_mut(r);
            for (cj, &j) in self.kept.iter().enumerate() {
                dst[j] = compact.at(r, cj);
            }
        }
        out
    }
}

impl HdClassifier for SparseInstance {
    fn kind(&self) -> &'static str {
        "sparsehd"
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn d(&self) -> usize {
        self.d
    }
    fn decode_activations(&self, enc: &Matrix) -> Matrix {
        activations(enc, &self.scatter())
    }
    fn predict(&self, enc: &Matrix) -> Vec<i32> {
        argmax_rows(&self.decode_activations(enc))
    }
    fn fault_surface(&self) -> FaultSurface {
        FaultSurface::new(vec![self.compact.plane("prototypes_retained")])
    }
    fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]) {
        debug_assert_eq!(plane, 0);
        self.compact.apply(mask);
    }
    fn apply_fault(&mut self, plane: usize, fault: &PlaneFault) {
        debug_assert_eq!(plane, 0);
        self.compact.apply_fault(fault);
    }
}

/// Build the SparseHD instance from a trained [`SparseHdModel`].
pub fn sparsehd(model: &SparseHdModel, precision: Precision) -> Box<dyn HdClassifier> {
    let kept = kept_indices(&model.mask);
    let compact = gather_cols(&model.prototypes, &kept);
    Box::new(SparseInstance {
        classes: model.classes(),
        d: model.mask.len(),
        kept,
        compact: PlaneState::build(&compact, precision),
    })
}

// ---------------------------------------------------------------------
// LogHD (dense widths: f32, b2, b4)
// ---------------------------------------------------------------------

/// LogHD at a width with no packed kernel: bundle plane + profile
/// deviation/mean planes, decoded through the dense f32 pipeline.
struct LogHdDenseInstance {
    classes: usize,
    d: usize,
    book: Codebook,
    bundles: PlaneState,
    profiles: ProfilePlanes,
}

impl LogHdDenseInstance {
    fn model(&self) -> LogHdModel {
        LogHdModel {
            classes: self.classes,
            d: self.d,
            book: self.book.clone(),
            bundles: self.bundles.dense(),
            profiles: self.profiles.assemble(),
        }
    }
}

impl HdClassifier for LogHdDenseInstance {
    fn kind(&self) -> &'static str {
        "loghd"
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn d(&self) -> usize {
        self.d
    }
    fn decode_activations(&self, enc: &Matrix) -> Matrix {
        let mut dists = self.model().decode_dists(enc);
        for v in dists.data_mut() {
            *v = -*v;
        }
        dists
    }
    fn predict(&self, enc: &Matrix) -> Vec<i32> {
        self.model().predict(enc)
    }
    fn fault_surface(&self) -> FaultSurface {
        let mut planes = vec![self.bundles.plane("bundles")];
        planes.extend(self.profiles.planes());
        FaultSurface::new(planes)
    }
    fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]) {
        if plane == 0 {
            self.bundles.apply(mask);
        } else {
            self.profiles.apply(plane - 1, mask);
        }
    }
    fn apply_fault(&mut self, plane: usize, fault: &PlaneFault) {
        if plane == 0 {
            self.bundles.apply_fault(fault);
        } else {
            self.profiles.apply_fault(plane - 1, fault);
        }
    }
}

/// Build the LogHD instance for `precision`: the packed twin at 1/8 bits
/// (inference stays in the packed domain), the dense plane form elsewhere.
pub fn loghd(model: &LogHdModel, precision: Precision) -> Box<dyn HdClassifier> {
    match precision {
        Precision::B1 | Precision::B8 => {
            Box::new(QuantizedLogHdModel::from_model(model, precision))
        }
        p => Box::new(LogHdDenseInstance {
            classes: model.classes,
            d: model.d,
            book: model.book.clone(),
            bundles: PlaneState::build(&model.bundles, p),
            profiles: ProfilePlanes::build(&model.profiles, p),
        }),
    }
}

// ---------------------------------------------------------------------
// Hybrid (LogHD bundles + SparseHD dimension mask)
// ---------------------------------------------------------------------

/// Hybrid at a dense width: the compacted bundle columns are the stored
/// plane (pruned dims are not stored), profiles as deviations + mean.
struct HybridDenseInstance {
    classes: usize,
    full_d: usize,
    book: Codebook,
    kept: Vec<usize>,
    bundles_compact: PlaneState,
    profiles: ProfilePlanes,
}

impl HybridDenseInstance {
    fn model(&self) -> LogHdModel {
        let compact = self.bundles_compact.dense();
        let mut bundles = Matrix::zeros(compact.rows(), self.full_d);
        for r in 0..compact.rows() {
            let dst = bundles.row_mut(r);
            for (cj, &j) in self.kept.iter().enumerate() {
                dst[j] = compact.at(r, cj);
            }
        }
        LogHdModel {
            classes: self.classes,
            d: self.full_d,
            book: self.book.clone(),
            bundles,
            profiles: self.profiles.assemble(),
        }
    }
}

impl HdClassifier for HybridDenseInstance {
    fn kind(&self) -> &'static str {
        "hybrid"
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn d(&self) -> usize {
        self.full_d
    }
    fn decode_activations(&self, enc: &Matrix) -> Matrix {
        let mut dists = self.model().decode_dists(enc);
        for v in dists.data_mut() {
            *v = -*v;
        }
        dists
    }
    fn predict(&self, enc: &Matrix) -> Vec<i32> {
        self.model().predict(enc)
    }
    fn fault_surface(&self) -> FaultSurface {
        let mut planes = vec![self.bundles_compact.plane("bundles_retained")];
        planes.extend(self.profiles.planes());
        FaultSurface::new(planes)
    }
    fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]) {
        if plane == 0 {
            self.bundles_compact.apply(mask);
        } else {
            self.profiles.apply(plane - 1, mask);
        }
    }
    fn apply_fault(&mut self, plane: usize, fault: &PlaneFault) {
        if plane == 0 {
            self.bundles_compact.apply_fault(fault);
        } else {
            self.profiles.apply_fault(plane - 1, fault);
        }
    }
}

/// Hybrid at a packed width: the column-compacted model quantized into a
/// [`QuantizedLogHdModel`] (activation gain restores the full-width
/// query-normalization scale its profiles were trained against);
/// queries are gathered to the retained coordinates inside `predict`.
struct HybridPackedInstance {
    qm: QuantizedLogHdModel,
    kept: Vec<usize>,
    full_d: usize,
}

impl HdClassifier for HybridPackedInstance {
    fn kind(&self) -> &'static str {
        "hybrid"
    }
    fn classes(&self) -> usize {
        self.qm.classes
    }
    fn d(&self) -> usize {
        self.full_d
    }
    fn decode_activations(&self, enc: &Matrix) -> Matrix {
        self.qm.decode_activations(&gather_cols(enc, &self.kept))
    }
    fn predict(&self, enc: &Matrix) -> Vec<i32> {
        QuantizedLogHdModel::predict(&self.qm, &gather_cols(enc, &self.kept))
    }
    fn fault_surface(&self) -> FaultSurface {
        self.qm.fault_surface()
    }
    fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]) {
        self.qm.apply_flips(plane, mask);
    }
    fn apply_fault(&mut self, plane: usize, fault: &PlaneFault) {
        self.qm.apply_fault(plane, fault);
    }
    fn refresh(&mut self) {
        self.qm.refresh();
    }
}

/// Build the Hybrid instance for `precision`.
pub fn hybrid(model: &HybridModel, precision: Precision) -> Box<dyn HdClassifier> {
    match precision {
        Precision::B1 | Precision::B8 => {
            let kept = kept_indices(&model.mask);
            let full_d = model.inner.d;
            let inner = LogHdModel {
                classes: model.inner.classes,
                d: kept.len(),
                book: model.inner.book.clone(),
                bundles: gather_cols(&model.inner.bundles, &kept),
                profiles: model.inner.profiles.clone(),
            };
            let mut qm = QuantizedLogHdModel::from_model(&inner, precision);
            // The hybrid profiles were trained against full-width query
            // normalization; restore that scale on the compacted model.
            qm.set_activation_gain((kept.len() as f32 / full_d as f32).sqrt());
            Box::new(HybridPackedInstance { qm, kept, full_d })
        }
        p => {
            let kept = kept_indices(&model.mask);
            Box::new(HybridDenseInstance {
                classes: model.inner.classes,
                full_d: model.inner.d,
                book: model.inner.book.clone(),
                bundles_compact: PlaneState::build(
                    &gather_cols(&model.inner.bundles, &kept),
                    p,
                ),
                kept,
                profiles: ProfilePlanes::build(&model.inner.profiles, p),
            })
        }
    }
}

// ---------------------------------------------------------------------
// DecoHD
// ---------------------------------------------------------------------

/// DecoHD at one precision: basis plane + coefficient plane, with the
/// dense scoring twin of the *current* plane contents cached (rebuilt
/// by `refresh` after fault injection) — the serving path (`ZooEngine`)
/// calls `predict` per batch and must not re-dequantize per batch.
struct DecoHdInstance {
    classes: usize,
    d: usize,
    basis: PlaneState,
    coeffs: PlaneState,
    dense: DecoHdModel,
}

impl DecoHdInstance {
    fn rebuild_dense(&mut self) {
        self.dense = DecoHdModel { basis: self.basis.dense(), coeffs: self.coeffs.dense() };
    }
}

impl HdClassifier for DecoHdInstance {
    fn kind(&self) -> &'static str {
        "decohd"
    }
    fn classes(&self) -> usize {
        self.classes
    }
    fn d(&self) -> usize {
        self.d
    }
    fn decode_activations(&self, enc: &Matrix) -> Matrix {
        self.dense.scores(enc)
    }
    fn predict(&self, enc: &Matrix) -> Vec<i32> {
        self.dense.predict(enc)
    }
    fn fault_surface(&self) -> FaultSurface {
        FaultSurface::new(vec![self.basis.plane("basis"), self.coeffs.plane("coeffs")])
    }
    fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]) {
        match plane {
            0 => self.basis.apply(mask),
            _ => self.coeffs.apply(mask),
        }
    }
    fn apply_fault(&mut self, plane: usize, fault: &PlaneFault) {
        match plane {
            0 => self.basis.apply_fault(fault),
            _ => self.coeffs.apply_fault(fault),
        }
    }
    fn refresh(&mut self) {
        self.rebuild_dense();
    }
}

/// Build the DecoHD instance from a trained [`DecoHdModel`].
pub fn decohd(model: &DecoHdModel, precision: Precision) -> Box<dyn HdClassifier> {
    let mut inst = DecoHdInstance {
        classes: model.classes(),
        d: model.d(),
        basis: PlaneState::build(&model.basis, precision),
        coeffs: PlaneState::build(&model.coeffs, precision),
        dense: DecoHdModel { basis: Matrix::zeros(0, 0), coeffs: Matrix::zeros(0, 0) },
    };
    inst.rebuild_dense();
    Box::new(inst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::inject_value_faults;
    use crate::util::rng::SplitMix64;

    fn prototypes() -> Matrix {
        let mut rng = SplitMix64::new(3);
        let mut h = Matrix::from_vec(4, 64, rng.normals_f32(256));
        tensor::normalize_rows(&mut h);
        h
    }

    #[test]
    fn conventional_instance_matches_direct_model_when_clean() {
        let h = prototypes();
        let mut rng = SplitMix64::new(5);
        let enc = Matrix::from_vec(6, 64, rng.normals_f32(6 * 64));
        let inst = conventional(&h, Precision::F32);
        let direct = crate::baselines::ConventionalModel::new(h.clone()).predict(&enc);
        assert_eq!(inst.predict(&enc), direct);
        assert_eq!(inst.stored_bits(), 4 * 64 * 32);
        assert_eq!(inst.kind(), "conventional");
        assert_eq!((inst.classes(), inst.d()), (4, 64));
    }

    #[test]
    fn sparse_instance_keeps_pruned_dims_outside_the_surface() {
        let h = prototypes();
        let model = SparseHdModel::from_prototypes(&h, 0.5);
        let mut inst = sparsehd(&model, Precision::B8);
        assert_eq!(inst.stored_bits(), model.retained() * 4 * 8);
        let mut rng = SplitMix64::new(9);
        let flips = inject_value_faults(inst.as_mut(), 0.5, &mut rng);
        assert!(flips > 0);
        // pruned dims contribute nothing: corrupting them is impossible,
        // so the activations of a pruned-only query are exactly zero
        let mut pruned_query = vec![0.0f32; 64];
        for (j, keep) in model.mask.iter().enumerate() {
            if !keep {
                pruned_query[j] = 1.0;
            }
        }
        let a = inst.decode_activations(&Matrix::from_vec(1, 64, pruned_query));
        assert!(a.data().iter().all(|v| *v == 0.0));
    }

    #[test]
    fn dense_planes_flip_like_the_reference_appliers() {
        let h = prototypes();
        let mut inst = conventional(&h, Precision::F32);
        let mut rng = SplitMix64::new(21);
        inject_value_faults(inst.as_mut(), 0.4, &mut rng);
        // reference: the pre-trait corrupt() on the same stream
        let mut rng2 = SplitMix64::new(21);
        let want = crate::eval::corrupt(&h, Precision::F32, 0.4, &mut rng2);
        let got = inst.decode_activations(&Matrix::from_vec(1, 64, vec![1.0; 64]));
        let wref = activations(&Matrix::from_vec(1, 64, vec![1.0; 64]), &want);
        assert_eq!(got.data(), wref.data());
    }

    #[test]
    fn surfaces_carry_matrix_geometry() {
        let h = prototypes();
        let f = conventional(&h, Precision::F32).fault_surface();
        assert_eq!((f.planes[0].rows, f.planes[0].cols, f.planes[0].bits), (4, 64, 32));
        let q = conventional(&h, Precision::B8).fault_surface();
        assert_eq!((q.planes[0].rows, q.planes[0].cols, q.planes[0].bits), (4, 64, 8));
        assert_eq!(f.planes[0].total_bits(), 4 * 64 * 32);
    }

    #[test]
    fn analog_faults_perturb_dense_and_packed_planes() {
        use crate::faults::FaultModel;
        use crate::model::inject_faults;
        let h = prototypes();
        let models = [
            FaultModel::GaussianDrift { sigma: 0.5 },
            FaultModel::StuckAt { frac: 0.3, polarity: crate::faults::StuckPolarity::Mixed },
            FaultModel::LineFailure { rate: 0.4, span: 2 },
        ];
        let probe = Matrix::from_vec(1, 64, vec![1.0; 64]);
        for precision in [Precision::F32, Precision::B8, Precision::B1] {
            for fm in &models {
                let mut inst = conventional(&h, precision);
                let clean = inst.decode_activations(&probe);
                let mut rng = SplitMix64::new(31);
                let touched = inject_faults(inst.as_mut(), fm, &mut rng);
                assert!(touched > 0, "{precision:?}/{fm:?}: nothing touched");
                let noisy = inst.decode_activations(&probe);
                assert_ne!(clean.data(), noisy.data(), "{precision:?}/{fm:?}: plane unchanged");
            }
        }
    }

    /// Property pin: `argmax_rows` resolves ties to the lowest index on
    /// crafted tie patterns and on random matrices (checked against a
    /// naive strictly-greater scan, which is first-on-ties by
    /// construction).
    #[test]
    fn argmax_rows_breaks_ties_lowest_index_wins() {
        // Crafted ties: leading tie, full-row tie, tie at the end.
        let m = Matrix::from_vec(
            4,
            4,
            vec![
                2.0, 2.0, 1.0, 0.0, // cols 0,1 tie -> 0
                5.0, 5.0, 5.0, 5.0, // all tie -> 0
                0.0, 1.0, 3.0, 3.0, // cols 2,3 tie -> 2
                -1.0, -1.0, -2.0, -1.0, // cols 0,1,3 tie -> 0
            ],
        );
        assert_eq!(argmax_rows(&m), vec![0, 0, 2, 0]);

        // Random property: quantize values to a coarse grid so ties are
        // frequent, then compare against the naive first-max scan.
        let mut rng = SplitMix64::new(0xA56A);
        for case in 0..64 {
            let rows = 1 + (case % 7);
            let cols = 1 + (case % 11);
            let vals: Vec<f32> =
                rng.normals_f32(rows * cols).iter().map(|v| (v * 2.0).round() / 2.0).collect();
            let m = Matrix::from_vec(rows, cols, vals);
            let naive: Vec<i32> = (0..rows)
                .map(|i| {
                    let row = m.row(i);
                    let mut best = 0usize;
                    for (j, &v) in row.iter().enumerate() {
                        if v > row[best] {
                            best = j;
                        }
                    }
                    best as i32
                })
                .collect();
            assert_eq!(argmax_rows(&m), naive, "case {case}: tie broken away from lowest index");
        }
    }

    #[test]
    fn distance_margins_follow_the_argmin_tie_discipline() {
        let d = Matrix::from_vec(
            3,
            3,
            vec![
                1.0, 4.0, 2.0, // margin 1.0
                3.0, 3.0, 5.0, // tie -> margin 0
                0.5, 0.5, 0.5, // full tie -> margin 0
            ],
        );
        let mut margins = Vec::new();
        distance_margins_into(&d, &mut margins);
        assert_eq!(margins, vec![1.0, 0.0, 0.0]);

        // Single class: no runner-up, infinite margin.
        let d1 = Matrix::from_vec(2, 1, vec![3.0, 7.0]);
        distance_margins_into(&d1, &mut margins);
        assert_eq!(margins.len(), 2);
        assert!(margins.iter().all(|m| m.is_infinite()));
    }

    #[test]
    fn profile_planes_roundtrip_cleanly_at_f32() {
        let mut rng = SplitMix64::new(11);
        let p = Matrix::from_vec(5, 3, rng.normals_f32(15));
        let planes = ProfilePlanes::build(&p, Precision::F32);
        let back = planes.assemble();
        for (a, b) in p.data().iter().zip(back.data()) {
            assert!((a - b).abs() < 1e-6);
        }
        // n column planes + the mean plane, in stream order
        let surface = planes.planes();
        assert_eq!(surface.len(), 4);
        assert_eq!(surface[0].label, "profiles[0]");
        assert_eq!(surface[3].label, "profile_mean");
    }
}

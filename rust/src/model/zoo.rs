//! The model zoo: a string-keyed [`ModelSpec`] registry mapping artifact
//! kinds to loaders, family tags, and serving-engine construction.
//!
//! Registering a family here is the *one* wiring step that makes it:
//!
//! - loadable — `persist::load_any` resolves the artifact's
//!   `ModelCard::kind` through [`lookup`] and calls the spec's loader;
//! - servable — `coordinator::registry` builds its per-replica engine
//!   factories via [`engine_factories`];
//! - inspectable — `loghd inspect` prints the spec next to the
//!   trait-reported [`stored_bits`](crate::model::HdClassifier::stored_bits)
//!   of the loaded instance.
//!
//! The worked example is `native-decohd` (`baselines::decohd`): one
//! table row below, zero changes in the serving or persistence layers.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::worker::{
    CascadeCounters, CascadeEngine, ConventionalEngine, EngineFactory, NativeEngine, ZooEngine,
};
use crate::loghd::persist::{self, LoadedModel};
use crate::model::instances;
use crate::quant::Precision;
use crate::runtime::artifact::ModelCard;

/// One registered artifact kind: how it identifies on disk, which
/// family it belongs to, and how to load it.
pub struct ModelSpec {
    /// Artifact kind key — the `model.json` / manifest `kind` value.
    pub kind: &'static str,
    /// Family tag (matches [`HdClassifier::kind`] and
    /// [`LoadedModel::kind`]).
    ///
    /// [`HdClassifier::kind`]: crate::model::HdClassifier::kind
    pub family: &'static str,
    /// One-line description for `loghd inspect` / docs.
    pub description: &'static str,
    loader: fn(&Path) -> Result<LoadedModel>,
}

impl ModelSpec {
    /// Load the artifact at `dir` as this kind.
    pub fn load(&self, dir: &Path) -> Result<LoadedModel> {
        (self.loader)(dir)
    }
}

fn load_native_loghd(dir: &Path) -> Result<LoadedModel> {
    let (e, m) = persist::load(dir)?;
    Ok(LoadedModel::LogHd(e, m))
}

fn load_native_conventional(dir: &Path) -> Result<LoadedModel> {
    let (e, m) = persist::load_conventional(dir)?;
    Ok(LoadedModel::Conventional(e, m))
}

fn load_native_decohd(dir: &Path) -> Result<LoadedModel> {
    let (e, m) = persist::load_decohd(dir)?;
    Ok(LoadedModel::DecoHd(e, m))
}

fn load_aot_bundle(dir: &Path) -> Result<LoadedModel> {
    let (e, m) = persist::load_from_aot_bundle(dir)?;
    Ok(LoadedModel::LogHd(e, m))
}

/// Every artifact kind the stack can load and serve.
pub const SPECS: &[ModelSpec] = &[
    ModelSpec {
        kind: "native-loghd",
        family: "loghd",
        description: "LogHD class-axis classifier: codebook bundles + activation profiles",
        loader: load_native_loghd,
    },
    ModelSpec {
        kind: "native-conventional",
        family: "conventional",
        description: "conventional HDC baseline: one prototype per class (O(C*D))",
        loader: load_native_conventional,
    },
    ModelSpec {
        kind: "native-decohd",
        family: "decohd",
        description: "DecoHD-style decomposed classifier: shared basis + per-class coefficients",
        loader: load_native_decohd,
    },
    ModelSpec {
        kind: "aot-bundle",
        family: "loghd",
        description: "Python AOT bundle (LogHD tensors + lowered HLO entries)",
        loader: load_aot_bundle,
    },
];

/// Find the spec for an artifact kind key.
pub fn lookup(kind: &str) -> Option<&'static ModelSpec> {
    SPECS.iter().find(|s| s.kind == kind)
}

/// Load any registered artifact directory. The kind probe is
/// [`ModelCard::load`] — the same probe the serving admission check
/// uses — and dispatch is the [`SPECS`] table.
pub fn load(dir: &Path) -> Result<LoadedModel> {
    let card = ModelCard::load(dir)?;
    let spec = lookup(&card.kind).with_context(|| {
        format!(
            "{}: unknown artifact kind '{}' (registered: {})",
            dir.display(),
            card.kind,
            kinds()
        )
    })?;
    spec.load(dir)
}

/// Comma-separated registered kind keys (for error messages / inspect).
pub fn kinds() -> String {
    SPECS.iter().map(|s| s.kind).collect::<Vec<_>>().join(", ")
}

/// Load an artifact and build one serving-engine factory per replica —
/// the single engine-dispatch point behind `coordinator::registry`.
/// Each replica owns its own engine instance (dense tensors cloned per
/// replica; packed precisions pack on the worker thread), which is what
/// lets replicas serve batches fully in parallel. Returns
/// `(family kind, feature width, factories)`.
pub fn engine_factories(
    path: &Path,
    precision: Precision,
    replicas: usize,
    label: &str,
) -> Result<(String, usize, Vec<EngineFactory>)> {
    let loaded =
        load(path).with_context(|| format!("loading artifact {}", path.display()))?;
    let kind = loaded.kind().to_string();
    let features = loaded.features();
    let factories: Vec<EngineFactory> = match loaded {
        LoadedModel::LogHd(encoder, model) => (0..replicas)
            .map(|_| {
                NativeEngine::factory_with_precision(
                    encoder.clone(),
                    model.clone(),
                    label.to_string(),
                    precision,
                )
            })
            .collect(),
        LoadedModel::Conventional(encoder, model) => (0..replicas)
            .map(|_| {
                ConventionalEngine::factory(
                    encoder.clone(),
                    model.clone(),
                    label.to_string(),
                    precision,
                )
            })
            .collect(),
        LoadedModel::DecoHd(encoder, model) => (0..replicas)
            .map(|_| {
                let encoder = encoder.clone();
                let model = model.clone();
                let label = label.to_string();
                Box::new(move || {
                    Ok(Box::new(ZooEngine::new(
                        encoder,
                        instances::decohd(&model, precision),
                        label,
                        precision,
                    )) as Box<dyn crate::coordinator::Engine>)
                }) as EngineFactory
            })
            .collect(),
    };
    Ok((kind, features, factories))
}

/// Load a LogHD artifact and build one [`CascadeEngine`] factory per
/// replica — the `--cascade` serving path. Every replica shares the one
/// `counters` Arc, so per-tenant tier-1/escalation telemetry aggregates
/// across the pool. Only the LogHD family carries the b1 twin + margin
/// decode the cascade is built from; other kinds are refused here (the
/// registry admission check will already have rejected most of them via
/// the missing `cascade_threshold`).
pub fn cascade_engine_factories(
    path: &Path,
    exact_precision: Precision,
    replicas: usize,
    label: &str,
    threshold: f32,
    counters: Arc<CascadeCounters>,
) -> Result<(String, usize, Vec<EngineFactory>)> {
    let loaded =
        load(path).with_context(|| format!("loading artifact {}", path.display()))?;
    let kind = loaded.kind().to_string();
    let features = loaded.features();
    match loaded {
        LoadedModel::LogHd(encoder, model) => {
            let factories: Vec<EngineFactory> = (0..replicas)
                .map(|_| {
                    CascadeEngine::factory_with_precision(
                        encoder.clone(),
                        model.clone(),
                        label.to_string(),
                        exact_precision,
                        threshold,
                        Arc::clone(&counters),
                    )
                })
                .collect();
            Ok((kind, features, factories))
        }
        other => bail!(
            "tenant '{label}': --cascade serves only the loghd family, got kind '{}'",
            other.kind()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_loaded_kind_uniquely() {
        let mut keys: Vec<&str> = SPECS.iter().map(|s| s.kind).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), SPECS.len(), "duplicate kind keys");
        for key in ["native-loghd", "native-conventional", "native-decohd", "aot-bundle"] {
            assert!(lookup(key).is_some(), "missing spec for {key}");
        }
        assert!(lookup("nope").is_none());
        assert!(kinds().contains("native-decohd"));
    }

    #[test]
    fn unknown_dir_errors_name_the_registry() {
        let dir = std::env::temp_dir().join("loghd_zoo_unknown_kind");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("model.json"),
            r#"{"format": 1, "kind": "martian", "classes": 2, "d": 8, "features": 4}"#,
        )
        .unwrap();
        let err = load(&dir).unwrap_err();
        assert!(err.to_string().contains("martian"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! The unified classifier core: one trait behind every model family.
//!
//! Before this module existed, each classifier family (LogHD f32, the
//! packed b1/b8 twin, the conventional baseline, SparseHD, Hybrid) was
//! hand-wired separately into the sweep engine, the equal-memory
//! campaign solver, the serving registry, and persistence — every new
//! family or scenario cost five divergent match arms. The core
//! collapses those surfaces onto three contracts:
//!
//! - [`HdClassifier`] — the behavioural trait: `predict` /
//!   [`decode_activations`](HdClassifier::decode_activations), exact
//!   stored-size accounting ([`stored_bits`](HdClassifier::stored_bits)),
//!   and the fault contract below. Every family implements it at every
//!   serving precision (see [`instances`]).
//! - [`FaultSurface`] — the enumeration of *stored bit-planes* a model
//!   exposes to memory upsets, with one uniform applier
//!   ([`HdClassifier::apply_flips`]) and one shared injection driver
//!   ([`inject_value_faults`]). Budget accounting and fault injection
//!   read the **same** enumeration, so "equal memory" cells in
//!   `eval::campaign` cannot drift from what the injector actually
//!   corrupts: `stored_bits` *is* the surface size by construction.
//! - [`zoo`] — the string-keyed [`ModelSpec`](zoo::ModelSpec) registry
//!   mapping artifact kinds to loaders and serving-engine factories.
//!   `persist::load_any`, the serving registry, and `loghd inspect`
//!   all dispatch through it; registering a family once makes it
//!   loadable, servable, and inspectable everywhere.
//!
//! # Fault-stream discipline (why plane order is part of the contract)
//!
//! The Monte-Carlo campaign derives one [`SplitMix64`] stream per grid
//! cell and the golden conformance suite pins campaign artifacts
//! byte-for-byte. [`inject_value_faults`] therefore draws one
//! [`faults::value_flip_mask`] per plane, **in the order the surface
//! enumerates them** — the same order the pre-trait corruption helpers
//! (`eval::sweep::corrupt*`) consumed the stream in. A family's
//! `fault_surface` must keep its plane order stable or its campaign
//! numbers silently change; `rust/tests/trait_parity.rs` pins every
//! migrated family against the direct pre-refactor call sequence.
//!
//! See `docs/ARCHITECTURE.md` for the layer map and the
//! add-a-new-family checklist (worked example: `baselines::decohd`).

pub mod instances;
pub mod zoo;

use crate::faults;
use crate::tensor::Matrix;
use crate::util::rng::SplitMix64;

/// One stored bit-plane of a classifier: a `rows × cols` grid of
/// `bits`-bit fields, addressable by the per-value fault model and —
/// row-granularly — by the correlated line-failure model (`faults`
/// module). Geometry is part of the surface contract: the analog
/// samplers need to know where one stored row ends and the next
/// begins.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlane {
    /// Human-readable name (`loghd inspect` prints these).
    pub label: String,
    /// Stored rows (word lines) in the plane.
    pub rows: usize,
    /// Stored values per row.
    pub cols: usize,
    /// Bits per stored value (32 for raw f32 planes).
    pub bits: u32,
}

impl FaultPlane {
    /// A flat plane: one row of `values` fields. Kept for surfaces with
    /// no meaningful row structure (vectors, means).
    pub fn new(label: impl Into<String>, values: usize, bits: u32) -> Self {
        Self { label: label.into(), rows: 1, cols: values, bits }
    }

    /// A plane with explicit `rows × cols` geometry (matrices).
    pub fn with_shape(label: impl Into<String>, rows: usize, cols: usize, bits: u32) -> Self {
        Self { label: label.into(), rows, cols, bits }
    }

    /// Number of stored values in the plane.
    pub fn values(&self) -> usize {
        self.rows * self.cols
    }

    /// Total bits this plane stores.
    pub fn total_bits(&self) -> usize {
        self.values() * self.bits as usize
    }

    /// Value-domain label (`loghd inspect` prints these): what one
    /// stored field of this plane means to the analog rail mapping.
    pub fn domain(&self) -> &'static str {
        match self.bits {
            32 => "f32",
            1 => "sign",
            _ => "levels",
        }
    }
}

/// The enumeration of every stored bit-plane a classifier exposes to
/// memory upsets — the model's *entire* stored representation. Plane
/// order is part of the contract (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSurface {
    pub planes: Vec<FaultPlane>,
}

impl FaultSurface {
    pub fn new(planes: Vec<FaultPlane>) -> Self {
        Self { planes }
    }

    /// Total stored bits across every plane — the one number both the
    /// equal-memory solver and the fault injector see.
    pub fn total_bits(&self) -> usize {
        self.planes.iter().map(FaultPlane::total_bits).sum()
    }
}

/// A hyperdimensional classifier at a concrete serving precision: the
/// uniform surface `eval`, `faults`, serving, and the CLI dispatch on.
///
/// Implementations must keep `predict` bit-identical to their family's
/// reference path (pinned by `rust/tests/trait_parity.rs`) and must
/// enumerate [`fault_surface`](Self::fault_surface) in a stable order.
pub trait HdClassifier: Send {
    /// Family tag (`"loghd"`, `"conventional"`, `"sparsehd"`,
    /// `"hybrid"`, `"decohd"`) — matches the zoo registry's family keys.
    fn kind(&self) -> &'static str;

    /// Number of classes the classifier decides between.
    fn classes(&self) -> usize;

    /// Encoded query width `predict` expects (always the full
    /// hypervector dimension D — masked families gather internally).
    fn d(&self) -> usize;

    /// Per-class decision scores (B, C), argmax = predicted label.
    /// Distance-decoded families return negated distances.
    fn decode_activations(&self, enc: &Matrix) -> Matrix;

    /// Predicted labels for encoded queries.
    fn predict(&self, enc: &Matrix) -> Vec<i32>;

    /// Enumerate the stored bit-planes (order is contractual).
    fn fault_surface(&self) -> FaultSurface;

    /// Apply a sampled per-value flip mask (`(victim, bit)` pairs,
    /// victims strictly increasing) to plane `plane` of the surface.
    fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]);

    /// Apply a sampled plane fault in the *value domain*: digital flips
    /// route through [`apply_flips`](Self::apply_flips); analog faults
    /// (drift / stuck-at / line failures) perturb the stored values via
    /// their storage domain's rail mapping (`faults::apply_analog_f32`
    /// for f32 planes, `quant::apply_analog_packed` for packed ones).
    ///
    /// The default covers digital flips only, so legacy/mock
    /// implementations keep working; every in-tree family overrides it
    /// with its plane routing.
    fn apply_fault(&mut self, plane: usize, fault: &faults::PlaneFault) {
        match fault {
            faults::PlaneFault::Flips(mask) => self.apply_flips(plane, mask),
            other => panic!(
                "{}: analog fault {:?} not supported by this classifier",
                self.kind(),
                other
            ),
        }
    }

    /// Re-derive any cached views after direct mutation of the stored
    /// state. Called once by [`inject_faults`] after all planes.
    fn refresh(&mut self) {}

    /// Exact stored model size in bits — by default the fault-surface
    /// total, so budget accounting and the corruption target are the
    /// same bits by construction.
    fn stored_bits(&self) -> usize {
        self.fault_surface().total_bits()
    }
}

/// The one fault-injection driver every family and fault model share:
/// walk the stored bit-planes in surface order, sample one
/// [`faults::sample_plane_fault`] realization per plane from `rng`,
/// apply the non-empty ones, refresh. Returns the number of stored
/// values touched.
///
/// For [`faults::FaultModel::BitFlip`] this draws exactly one
/// [`faults::value_flip_mask`] per plane — the stream discipline of the
/// pre-trait `eval::sweep::corrupt*` helpers — so the digital campaign
/// goldens are byte-identical through this driver.
pub fn inject_faults(
    model: &mut dyn HdClassifier,
    fm: &faults::FaultModel,
    rng: &mut SplitMix64,
) -> usize {
    let surface = model.fault_surface();
    let mut touched = 0;
    for (i, plane) in surface.planes.iter().enumerate() {
        let fault = faults::sample_plane_fault(fm, plane.rows, plane.cols, plane.bits, rng);
        if !fault.is_empty() {
            model.apply_fault(i, &fault);
        }
        touched += fault.touched(plane.cols);
    }
    model.refresh();
    touched
}

/// Digital bit-flip injection at per-value probability `p` — the
/// original driver, now an alias for [`inject_faults`] at
/// [`faults::FaultModel::BitFlip`] (same stream, same flips).
pub fn inject_value_faults(model: &mut dyn HdClassifier, p: f64, rng: &mut SplitMix64) -> usize {
    inject_faults(model, &faults::FaultModel::BitFlip { p }, rng)
}

/// Stored value count of a LogHD-shaped model: `n` bundles of width
/// `d_kept` plus the (C, n) activation profiles stored as per-column
/// deviations *and* their n-vector cross-class mean (every part a fault
/// target — see `eval::sweep::corrupt_profiles`).
///
/// This is the **single** accounting rule shared by
/// `LogHdModel::memory_floats`, `HybridModel::memory_floats`,
/// `QuantizedLogHdModel::memory_bits`, and the equal-memory campaign
/// solver (`eval::campaign::stored_bits`); before it existed the model
/// methods dropped the `+ n` mean term and the two paths could drift.
pub fn loghd_stored_values(n: usize, d_kept: usize, classes: usize) -> usize {
    n * d_kept + classes * n + n
}

#[cfg(test)]
mod tests {
    use super::*;

    struct TwoPlane {
        f32s: Vec<f32>,
        packed: crate::quant::PackedTensor,
        refreshed: bool,
    }

    impl HdClassifier for TwoPlane {
        fn kind(&self) -> &'static str {
            "test"
        }
        fn classes(&self) -> usize {
            2
        }
        fn d(&self) -> usize {
            self.f32s.len()
        }
        fn decode_activations(&self, enc: &Matrix) -> Matrix {
            Matrix::zeros(enc.rows(), 2)
        }
        fn predict(&self, enc: &Matrix) -> Vec<i32> {
            vec![0; enc.rows()]
        }
        fn fault_surface(&self) -> FaultSurface {
            FaultSurface::new(vec![
                FaultPlane::new("dense", self.f32s.len(), 32),
                FaultPlane::new("packed", self.packed.count(), self.packed.bits()),
            ])
        }
        fn apply_flips(&mut self, plane: usize, mask: &[(usize, u32)]) {
            match plane {
                0 => {
                    for &(v, bit) in mask {
                        self.f32s[v] = f32::from_bits(self.f32s[v].to_bits() ^ (1 << bit));
                    }
                }
                1 => {
                    let bits = self.packed.bits() as usize;
                    for &(v, bit) in mask {
                        self.packed.flip_bit(v * bits + bit as usize);
                    }
                }
                other => panic!("no plane {other}"),
            }
        }
        fn refresh(&mut self) {
            self.refreshed = true;
        }
    }

    fn two_plane() -> TwoPlane {
        TwoPlane {
            f32s: vec![1.0; 40],
            packed: crate::quant::PackedTensor::new(8, 100),
            refreshed: false,
        }
    }

    #[test]
    fn driver_consumes_the_reference_stream() {
        // The driver must draw exactly one value_flip_mask per plane, in
        // surface order — the stream the direct appliers consume.
        let mut m = two_plane();
        let mut rng = SplitMix64::new(42);
        let flips = inject_value_faults(&mut m, 0.3, &mut rng);

        let mut reference = two_plane();
        let mut rng2 = SplitMix64::new(42);
        let n1 = faults::flip_values_f32(&mut reference.f32s, 0.3, &mut rng2);
        let n2 = faults::flip_values_packed(&mut reference.packed, 0.3, &mut rng2);
        assert_eq!(flips, n1 + n2);
        assert_eq!(m.f32s, reference.f32s);
        assert_eq!(m.packed, reference.packed);
        assert!(m.refreshed);
    }

    #[test]
    fn zero_probability_draws_and_flips_nothing() {
        let mut m = two_plane();
        let mut rng = SplitMix64::new(7);
        let before = rng.clone();
        assert_eq!(inject_value_faults(&mut m, 0.0, &mut rng), 0);
        assert_eq!(rng.next_u64(), before.clone().next_u64(), "p=0 must not consume the stream");
        assert!(m.f32s.iter().all(|v| *v == 1.0));
    }

    #[test]
    fn stored_bits_is_surface_total() {
        let m = two_plane();
        assert_eq!(m.stored_bits(), 40 * 32 + 100 * 8);
        assert_eq!(m.fault_surface().total_bits(), m.stored_bits());
    }

    #[test]
    fn plane_geometry_accounting() {
        let flat = FaultPlane::new("vec", 48, 8);
        assert_eq!((flat.rows, flat.cols, flat.values()), (1, 48, 48));
        let grid = FaultPlane::with_shape("mat", 6, 8, 32);
        assert_eq!(grid.values(), 48);
        assert_eq!(grid.total_bits(), 48 * 32);
        assert_eq!(grid.domain(), "f32");
        assert_eq!(FaultPlane::new("b", 4, 1).domain(), "sign");
        assert_eq!(FaultPlane::new("q", 4, 8).domain(), "levels");
    }

    #[test]
    fn analog_driver_matches_digital_for_bitflip() {
        // inject_faults(BitFlip{p}) must be the digital driver exactly:
        // same stream, same flips, same touched count.
        let mut a = two_plane();
        let mut b = two_plane();
        let na = inject_value_faults(&mut a, 0.25, &mut SplitMix64::new(5));
        let fm = faults::FaultModel::BitFlip { p: 0.25 };
        let nb = inject_faults(&mut b, &fm, &mut SplitMix64::new(5));
        assert_eq!(na, nb);
        assert_eq!(a.f32s, b.f32s);
        assert_eq!(a.packed, b.packed);
    }

    #[test]
    fn loghd_accounting_includes_the_profile_mean() {
        // n bundles * d + C*n deviations + n mean values.
        assert_eq!(loghd_stored_values(3, 256, 5), 3 * 256 + 5 * 3 + 3);
    }
}

//! Random-projection cosine encoder φ(x) = cos(xW + b), plus centering.
//!
//! The Rust twin of `python/compile/trainer.py::make_encoder` (same
//! SplitMix64 draw order: W normals row-major scaled 1/√F, then b
//! uniforms×2π), so a Rust-trained model and a Python-trained model with
//! the same seed share the same encoder.
//!
//! The encode hot path is a single fused pass: `W` is re-packed into
//! contiguous column panels at construction ([`simd::PackedPanels`])
//! and each output tile gets its GEMM, cos, bias and centering applied
//! while register-resident — no separate B·D libm `cos` sweep. On the
//! SIMD dispatch paths the cosine is the range-reduced polynomial
//! (≤ 1e-6 absolute from libm); the forced-scalar path keeps libm `cos`
//! and is bit-identical to the historical two-pass encoder.

use crate::tensor::{simd, Matrix};
use crate::util::rng::SplitMix64;
use crate::util::threadpool;

/// Encoder parameters. `mu` (the training-set mean encoding) is filled in
/// by the trainer; until then encodings are uncentered.
///
/// Memory note: both the row-major `w` (persistence / parity surface)
/// and its packed panel copy are kept, so an encoder costs ~2×F×D floats
/// per replica. F is small for every current dataset (≤ tens), which
/// keeps this far below the model tensors; if a wide-F workload ever
/// matters, the serving clone can drop `w` and keep only the panels.
#[derive(Debug, Clone)]
pub struct Encoder {
    /// (F, D) — private so it cannot drift from the packed copy below;
    /// read through [`Self::w`].
    w: Matrix,
    pub b: Vec<f32>,  // (D,)
    pub mu: Vec<f32>, // (D,) zeros until trained
    /// Column-panel packed copy of `w`, built once at construction for
    /// the fused encode kernel (in sync by construction: `w` is
    /// immutable after `from_parts`).
    wpack: simd::PackedPanels,
}

impl Encoder {
    /// Deterministic construction (Python parity).
    pub fn new(features: usize, d: usize, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let inv_sqrt_f = 1.0 / (features as f64).sqrt();
        let mut w = Matrix::zeros(features, d);
        for v in w.data_mut() {
            *v = (rng.normal() * inv_sqrt_f) as f32;
        }
        let b: Vec<f32> =
            (0..d).map(|_| (std::f64::consts::TAU * rng.uniform()) as f32).collect();
        Self::from_parts(w, b, vec![0.0; d])
    }

    /// Construct from pre-loaded tensors (artifact path).
    pub fn from_parts(w: Matrix, b: Vec<f32>, mu: Vec<f32>) -> Self {
        assert_eq!(w.cols(), b.len());
        assert_eq!(w.cols(), mu.len());
        let wpack = simd::PackedPanels::pack_columns(&w);
        Self { w, b, mu, wpack }
    }

    /// The projection matrix (F, D).
    pub fn w(&self) -> &Matrix {
        &self.w
    }

    /// The column-panel packed form of [`Self::w`] the fused encode
    /// kernel consumes (built at construction; exposed for benches).
    pub fn wpack(&self) -> &simd::PackedPanels {
        &self.wpack
    }

    pub fn features(&self) -> usize {
        self.w.rows()
    }

    pub fn dim(&self) -> usize {
        self.w.cols()
    }

    /// Encode a batch: (B, F) -> (B, D), centered by `mu`. One fused
    /// GEMM + cos + center pass per row, parallelized over rows.
    pub fn encode(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        self.encode_into(x, &mut out);
        out
    }

    /// [`Self::encode`] into a reused output matrix — the serving form:
    /// each replica keeps one encode scratch that settles at the batch
    /// high-water size and stops allocating. Every output element is
    /// written by the fused kernel, so the recycled buffer needs no
    /// clear.
    pub fn encode_into(&self, x: &Matrix, out: &mut Matrix) {
        assert_eq!(x.cols(), self.features(), "feature width mismatch");
        let d = self.dim();
        out.resize(x.rows(), d);
        if x.rows() == 0 {
            return;
        }
        let threads = threadpool::available_threads();
        threadpool::parallel_rows(out.data_mut(), d, threads, |i, row| {
            simd::encode_row(x.row(i), &self.wpack, &self.b, &self.mu, row);
        });
    }

    /// Fit the centering vector on (already encoded, uncentered) rows and
    /// return the previously-applied mu so callers can re-center.
    pub fn set_mu(&mut self, mu: Vec<f32>) {
        assert_eq!(mu.len(), self.dim());
        self.mu = mu;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let e1 = Encoder::new(7, 32, 5);
        let e2 = Encoder::new(7, 32, 5);
        assert_eq!(e1.w.data(), e2.w.data());
        assert_eq!(e1.b, e2.b);
        assert!(e1.b.iter().all(|v| (0.0..std::f32::consts::TAU + 1e-5).contains(v)));
    }

    #[test]
    fn encode_is_cos_of_affine() {
        let enc = Encoder::new(3, 8, 1);
        let x = Matrix::from_vec(2, 3, vec![0.1, -0.2, 0.3, 1.0, 0.5, -1.0]);
        let out = enc.encode(&x);
        assert_eq!(out.rows(), 2);
        assert_eq!(out.cols(), 8);
        // manual check of one element
        let mut acc = 0.0f32;
        for j in 0..3 {
            acc += x.at(1, j) * enc.w.at(j, 5);
        }
        let want = (acc + enc.b[5]).cos();
        assert!((out.at(1, 5) - want).abs() < 1e-5);
        // output bounded by 1 (mu = 0 here)
        assert!(out.data().iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }

    // Fused-encode agreement with the two-pass reference (including tail
    // panels at odd D) is pinned at the kernel level in
    // `tensor::simd::tests` and end-to-end by
    // `prop_fused_encode_matches_two_pass_reference` in
    // rust/tests/properties.rs.

    #[test]
    fn encode_empty_batch() {
        let enc = Encoder::new(4, 16, 3);
        let out = enc.encode(&Matrix::zeros(0, 4));
        assert_eq!((out.rows(), out.cols()), (0, 16));
    }

    #[test]
    fn centering_applied() {
        let mut enc = Encoder::new(3, 4, 2);
        let x = Matrix::from_vec(1, 3, vec![0.5, 0.5, 0.5]);
        let before = enc.encode(&x);
        enc.set_mu(vec![0.25; 4]);
        let after = enc.encode(&x);
        for j in 0..4 {
            assert!((after.at(0, j) - (before.at(0, j) - 0.25)).abs() < 1e-6);
        }
    }
}

//! Random-projection cosine encoder φ(x) = cos(xW + b), plus centering.
//!
//! The Rust twin of `python/compile/trainer.py::make_encoder` (same
//! SplitMix64 draw order: W normals row-major scaled 1/√F, then b
//! uniforms×2π), so a Rust-trained model and a Python-trained model with
//! the same seed share the same encoder. The encode hot path is a matmul
//! (see `tensor::matmul`) followed by a fused cos+center pass.

use crate::tensor::{self, Matrix};
use crate::util::rng::SplitMix64;
use crate::util::threadpool;

/// Encoder parameters. `mu` (the training-set mean encoding) is filled in
/// by the trainer; until then encodings are uncentered.
#[derive(Debug, Clone)]
pub struct Encoder {
    pub w: Matrix,      // (F, D)
    pub b: Vec<f32>,    // (D,)
    pub mu: Vec<f32>,   // (D,) zeros until trained
}

impl Encoder {
    /// Deterministic construction (Python parity).
    pub fn new(features: usize, d: usize, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let inv_sqrt_f = 1.0 / (features as f64).sqrt();
        let mut w = Matrix::zeros(features, d);
        for v in w.data_mut() {
            *v = (rng.normal() * inv_sqrt_f) as f32;
        }
        let b: Vec<f32> =
            (0..d).map(|_| (std::f64::consts::TAU * rng.uniform()) as f32).collect();
        Self { w, b, mu: vec![0.0; d] }
    }

    /// Construct from pre-loaded tensors (artifact path).
    pub fn from_parts(w: Matrix, b: Vec<f32>, mu: Vec<f32>) -> Self {
        assert_eq!(w.cols(), b.len());
        assert_eq!(w.cols(), mu.len());
        Self { w, b, mu }
    }

    pub fn features(&self) -> usize {
        self.w.rows()
    }

    pub fn dim(&self) -> usize {
        self.w.cols()
    }

    /// Encode a batch: (B, F) -> (B, D), centered by `mu`.
    pub fn encode(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.features(), "feature width mismatch");
        let mut out = tensor::matmul(x, &self.w);
        let d = self.dim();
        let threads = threadpool::available_threads();
        threadpool::parallel_rows(out.data_mut(), d, threads, |_, row| {
            for (v, (bb, mm)) in row.iter_mut().zip(self.b.iter().zip(self.mu.iter())) {
                *v = (*v + *bb).cos() - *mm;
            }
        });
        out
    }

    /// Fit the centering vector on (already encoded, uncentered) rows and
    /// return the previously-applied mu so callers can re-center.
    pub fn set_mu(&mut self, mu: Vec<f32>) {
        assert_eq!(mu.len(), self.dim());
        self.mu = mu;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let e1 = Encoder::new(7, 32, 5);
        let e2 = Encoder::new(7, 32, 5);
        assert_eq!(e1.w.data(), e2.w.data());
        assert_eq!(e1.b, e2.b);
        assert!(e1.b.iter().all(|v| (0.0..std::f32::consts::TAU + 1e-5).contains(v)));
    }

    #[test]
    fn encode_is_cos_of_affine() {
        let enc = Encoder::new(3, 8, 1);
        let x = Matrix::from_vec(2, 3, vec![0.1, -0.2, 0.3, 1.0, 0.5, -1.0]);
        let out = enc.encode(&x);
        assert_eq!(out.rows(), 2);
        assert_eq!(out.cols(), 8);
        // manual check of one element
        let mut acc = 0.0f32;
        for j in 0..3 {
            acc += x.at(1, j) * enc.w.at(j, 5);
        }
        let want = (acc + enc.b[5]).cos();
        assert!((out.at(1, 5) - want).abs() < 1e-5);
        // output bounded by 1 (mu = 0 here)
        assert!(out.data().iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }

    #[test]
    fn centering_applied() {
        let mut enc = Encoder::new(3, 4, 2);
        let x = Matrix::from_vec(1, 3, vec![0.5, 0.5, 0.5]);
        let before = enc.encode(&x);
        enc.set_mu(vec![0.25; 4]);
        let after = enc.encode(&x);
        for j in 0..4 {
            assert!((after.at(0, j) - (before.at(0, j) - 0.25)).abs() < 1e-6);
        }
    }
}

//! Offline shim for the subset of `anyhow` this workspace uses.
//!
//! The build environment has no registry access, so the real crate cannot
//! be fetched; this path dependency provides the same surface the code
//! relies on — `Result`, `Error`, the `Context` trait (on `Result` and
//! `Option`), and the `anyhow!` / `bail!` / `ensure!` macros — with the
//! same Display conventions (`{e}` prints the outermost message, `{e:#}`
//! the whole cause chain, `{e:?}` an indented "Caused by" report).
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error`: that is what keeps the blanket
//! `From<E: std::error::Error>` conversion (the `?` operator) coherent.

use std::fmt;

/// `Result` with a defaulted error type, as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus its cause chain, outermost
/// first. Contexts prepend; source chains of wrapped errors append.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: full chain, colon-separated.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain.iter().skip(1).enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Self { chain }
    }
}

mod private {
    /// Sealed unifier over "things that can become an [`Error`]": every
    /// std error, plus `Error` itself. `Error` is local and does not
    /// implement `std::error::Error`, so the two impls are coherent —
    /// the same trick the real crate plays.
    pub trait ToError {
        fn to_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> ToError for E {
        fn to_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl ToError for crate::Error {
        fn to_error(self) -> crate::Error {
            self
        }
    }
}

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::ToError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.to_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.to_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_and_context() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        fn outer() -> Result<()> {
            inner().context("loading config")
        }
        let e = outer().unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert_eq!(e.root_cause(), "no value 7");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable? {}", flag);
        }
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", f(true).unwrap_err()), "unreachable? true");
        let e: Error = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("root"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.chain().count(), 2);
    }
}

//! Offline stub of the `xla` PJRT bindings.
//!
//! This build environment has no registry access and no XLA shared
//! library, so the real crate cannot be used. This stub keeps the crate
//! API-compatible with the subset `loghd::runtime` calls: everything
//! type-checks, and every entry point that would touch PJRT returns
//! [`Error::Unavailable`] at runtime. The PJRT halves of the serving
//! bench, the artifact integration tests, and `loghd serve --artifacts`
//! already skip (loudly) when no artifact bundle is present, so the
//! native engine remains fully usable.
//!
//! To restore the real AOT path, replace the `xla = { path = "vendor/xla" }`
//! dependency with the actual bindings — no source changes needed.

use std::fmt;

/// Stub error: PJRT is not available in this build.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT backend unavailable (built against the vendored xla stub)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Stub of a PJRT client.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

/// Stub of a compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of a device buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of a parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of an XLA computation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Stub of a host literal.
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Self { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0]);
        assert!(lit.reshape(&[1]).is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("unavailable"));
    }
}
